"""deepseek-67b [dense] — llama-arch, GQA kv=8, 95 layers.
[arXiv:2401.02954; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-67b", family="dense",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=102400, head_dim=128, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-67b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, q_chunk=32, kv_chunk=32,
    )
