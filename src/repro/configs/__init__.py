"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from repro.models import ModelConfig

from . import (deepseek_67b, deepseek_v2_236b, granite_20b, llama3_2_3b,
               musicgen_large, phi3_5_moe, qwen2_vl_72b, xlstm_1_3b, yi_9b,
               zamba2_1_2b)

_MODULES = {
    "granite-20b": granite_20b,
    "deepseek-67b": deepseek_67b,
    "yi-9b": yi_9b,
    "llama3.2-3b": llama3_2_3b,
    "zamba2-1.2b": zamba2_1_2b,
    "xlstm-1.3b": xlstm_1_3b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe,
    "deepseek-v2-236b": deepseek_v2_236b,
    "musicgen-large": musicgen_large,
}

ARCHS = list(_MODULES.keys())

# shape grid assigned to every LM architecture
SHAPES: Dict[str, dict] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.smoke_config() if smoke else mod.full_config()


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic sequence mixing (see DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.supports_long_context
    return True
