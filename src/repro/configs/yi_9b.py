"""yi-9b [dense] — llama-arch GQA kv=4.  [arXiv:2403.04652; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab=64000, head_dim=128, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="yi-9b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, q_chunk=32, kv_chunk=32,
    )
