"""qwen2-vl-72b [vlm] — dense GQA backbone + M-RoPE; the vision frontend is a
STUB (input_specs supplies precomputed patch embeddings + positions).
[arXiv:2409.12191; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128, rope_theta=1000000.0,
        mrope_sections=(16, 24, 24), n_patches=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="qwen2-vl-72b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        mrope_sections=(2, 3, 3), n_patches=8, q_chunk=32, kv_chunk=32,
    )
