"""llama3.2-3b [dense] — small llama3, GQA kv=8, 128k vocab.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, head_dim=128, rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="llama3.2-3b-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=12, rope_theta=500000.0,
        q_chunk=32, kv_chunk=32,
    )
