"""deepseek-v2-236b [moe] — MLA (kv_lora=512, q_lora=1536, decoupled RoPE) +
160 routed experts top-6 + 2 shared experts; first layer dense FFN.
[arXiv:2405.04434; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-v2-236b", family="mla_moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab=102400, head_dim=192,
        n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
        capacity_factor=1.25, moe_layer_start=1,
        q_lora=1536, kv_lora=512, nope_head_dim=128, rope_head_dim=64,
        v_head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="deepseek-v2-smoke", family="mla_moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=256, head_dim=24,
        n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=64,
        capacity_factor=1.5, moe_layer_start=1,
        q_lora=32, kv_lora=16, nope_head_dim=16, rope_head_dim=8, v_head_dim=16,
        q_chunk=32, kv_chunk=32,
    )
