"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention block
invoked every 6 layers.  [arXiv:2411.15242; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000, head_dim=64,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, attn_every=6,
        scan_layers=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="zamba2-1.2b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, attn_every=2,
        ssm_chunk=16, scan_layers=False, q_chunk=32, kv_chunk=32,
    )
