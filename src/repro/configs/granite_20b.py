"""granite-20b [dense] — llama-arch code model, extreme GQA (kv=1).
[arXiv:2405.04324; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, head_dim=128, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="granite-20b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=256, head_dim=16, q_chunk=32, kv_chunk=32,
    )
