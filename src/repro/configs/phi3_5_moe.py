"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2 routing, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab=32064, head_dim=128, rope_theta=10000.0,
        n_experts=16, top_k=2, d_ff_expert=6400, capacity_factor=1.25,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="phi3.5-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=16,
        n_experts=4, top_k=2, d_ff_expert=96, capacity_factor=1.5,
        q_chunk=32, kv_chunk=32,
    )
