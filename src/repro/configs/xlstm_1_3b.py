"""xlstm-1.3b [ssm] — mLSTM blocks with sLSTM blocks at every 8th position
(ratio per the xLSTM paper).  d_ff=0: projections live inside the cells.
[arXiv:2405.04517; unverified]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-1.3b", family="xlstm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, head_dim=512,
        slstm_every=8, ssm_expand=2, scan_layers=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="xlstm-1.3b-smoke", family="xlstm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256, head_dim=16,
        slstm_every=2, ssm_expand=2, mlstm_chunk=16, scan_layers=False,
    )
