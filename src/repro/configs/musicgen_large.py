"""musicgen-large [audio] — decoder-only over 4 EnCodec codebooks (summed
codebook embeddings in, 4 LM heads out); the EnCodec frontend is a STUB
(input_specs supplies token grids).  [arXiv:2306.05284; hf]"""
from repro.models import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        arch="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64, codebooks=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        arch="musicgen-large-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=64, head_dim=16, codebooks=4,
        q_chunk=32, kv_chunk=32,
    )
