"""Trigger-orchestrated batched serving engine.

Requests arrive as CloudEvents; a *batcher* trigger aggregates up to
``max_batch`` requests (or fires on a flush timeout — same rich-trigger
machinery as the FL aggregator), its action runs prefill + N decode steps on
the mesh, and emits one termination event per request.  Scale-to-zero falls
out of Triggerflow: no requests → no events → the worker is reclaimed.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Triggerflow, termination_event
from repro.core.actions import register_pyfunc
from repro.core.triggers import make_trigger
from repro.models import Model, ModelConfig, unbox

_ENGINES: Dict[str, "ServingEngine"] = {}


class ServingEngine:
    def __init__(self, cfg: ModelConfig, tf: Triggerflow, workflow: str,
                 max_batch: int = 4, max_new_tokens: int = 16,
                 max_len: int = 256):
        self.cfg = cfg
        self.tf = tf
        self.workflow = workflow
        self.max_batch = max_batch
        self.max_new_tokens = max_new_tokens
        self.max_len = max_len
        self.model = Model(cfg)
        self.params = unbox(self.model.init(jax.random.PRNGKey(0)))
        self._prefill = jax.jit(lambda p, b: self.model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(self.model.decode)
        self.served = 0
        self.batches = 0
        _ENGINES[workflow] = self

    def deploy(self) -> None:
        self.tf.create_workflow(self.workflow, {"kind": "serving"})
        self.tf.add_trigger(self.workflow, make_trigger(
            "serve|request",
            condition={"name": "counter", "expected": self.max_batch,
                       "reset_on_fire": True},
            action={"name": "pyfunc", "func": "serve.batch", "engine": self.workflow},
            trigger_id=f"{self.workflow}/batcher",
            transient=False,
        ))

    def submit(self, request_id: str, prompt_tokens: List[int]) -> None:
        self.tf.publish(self.workflow, termination_event(
            "serve|request", result={"id": request_id, "prompt": prompt_tokens}))

    def flush(self) -> None:
        """Force the batcher to fire with a partial batch (timeout analogue)."""
        worker = self.tf.worker(self.workflow)
        ctx = worker.context_of(f"{self.workflow}/batcher")
        pending = ctx.get("count", 0)
        if pending:
            ctx["expected"] = pending

    def generate_batch(self, requests: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        B = len(requests)
        S = max(len(r["prompt"]) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r["prompt"]):] = r["prompt"]  # left-pad
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in range(B)]
        tok = jnp.argmax(logits, -1)[:, None]
        for _ in range(self.max_new_tokens):
            for i in range(B):
                outs[i].append(int(tok[i, 0]))
            logits, cache = self._decode(self.params, cache, {"tokens": tok})
            tok = jnp.argmax(logits, -1)[:, None]
        self.served += B
        self.batches += 1
        return [{"id": r["id"], "tokens": outs[i]} for i, r in enumerate(requests)]


def _serve_batch(ctx, event, params) -> None:
    eng = _ENGINES[params["engine"]]
    requests = [r for r in (ctx.get("fired_results") or []) if r]
    if not requests:
        return
    for out in eng.generate_batch(requests):
        ctx.produce(termination_event(f"serve|done|{out['id']}", result=out))


register_pyfunc("serve.batch", _serve_batch)
