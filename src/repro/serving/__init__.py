from .engine import ServingEngine
