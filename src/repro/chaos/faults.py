"""Deterministic fault injectors for the failure-policy plane.

Chaos here is *replayable*: every injection decision is a pure function of
``(seed, seam, key, n-th encounter)`` — no wall clock, no ``random`` module
state.  Two runs with the same seed and the same logical call sequence draw
the same fault schedule, which is what lets the soak assert *identical
committed results* across runs instead of merely "it survived".

The injectors wrap the real seams the runtime already hardens:

* ``ChaosEventStore`` — ``publish``/``publish_batch`` (an action's produced
  events vanish mid-fire: with a retry policy this surfaces as a retryable
  action error) and ``commit``/``commit_partitions`` (the §3.4 torn window:
  checkpointed but uncommitted, the batch must replay without double
  counting).
* ``ChaosStateStore`` — ``put_contexts_delta`` (a failed checkpoint: the
  worker keeps its dirty tracking and re-emits the deltas next attempt, or
  the shard dies and the replacement replays).
* ``tear_segment_tail`` — appends a torn (half-written) record to a durable
  segment file, the crash-mid-append state the locked-writer repair path
  must truncate.

Faults raise ``InjectedFault`` *before* the real call — the worst case for
the caller, which cannot know whether the operation happened.
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Dict, List, Optional, Tuple


class InjectedFault(RuntimeError):
    """A fault deliberately injected by a FaultPlan (never a real error)."""


class FaultPlan:
    """A seeded, replayable fault schedule.

    ``rates`` maps seam name → injection probability; ``max_faults`` caps
    injections per seam (bounds quarantine growth and guarantees the soak
    terminates).  Decisions are keyed by the *stable identity* of the
    operation (e.g. the event id) plus a per-key encounter counter, so a
    redelivered event draws a fresh number on each encounter — identical
    across runs, independent of shard interleaving.
    """

    def __init__(self, seed: int, rates: Optional[Dict[str, float]] = None,
                 max_faults: Optional[Dict[str, int]] = None) -> None:
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.max_faults = dict(max_faults or {})
        self._fired: Dict[str, int] = {}        # seam -> injections so far
        self._encounters: Dict[Tuple[str, str], int] = {}
        self.history: List[Tuple[str, str, int]] = []  # (seam, key, encounter)

    def _u(self, seam: str, key: str, n: int) -> float:
        h = zlib.crc32(f"{self.seed}:{seam}:{key}:{n}".encode())
        return h / 2 ** 32

    def decide(self, seam: str, key: str) -> bool:
        """True ⇒ inject a fault at ``seam`` for operation identity ``key``.

        The (seam, key) pair carries its own encounter counter: the first
        commit of event X and the replayed commit of event X are distinct
        draws, so a faulted operation does not fault forever.
        """
        rate = self.rates.get(seam, 0.0)
        if rate <= 0.0:
            return False
        cap = self.max_faults.get(seam)
        if cap is not None and self._fired.get(seam, 0) >= cap:
            return False
        k = (seam, key)
        n = self._encounters.get(k, 0)
        self._encounters[k] = n + 1
        if self._u(seam, key, n) < rate:
            self._fired[seam] = self._fired.get(seam, 0) + 1
            self.history.append((seam, key, n))
            return True
        return False

    def check(self, seam: str, key: str) -> None:
        """``decide`` + raise: the one-liner the store wrappers use."""
        if self.decide(seam, key):
            raise InjectedFault(f"{seam}[{key}] (seed={self.seed})")

    def faults_injected(self) -> Dict[str, int]:
        return dict(self._fired)


def _batch_key(events) -> str:
    """Stable identity of a publish/commit batch: its first member."""
    if not events:
        return "-"
    first = events[0]
    return first if isinstance(first, str) else first.id


class ChaosEventStore:
    """Wraps any event store; injects at the publish, commit and consume
    seams.

    A consume fault fires *before* the inner call ever runs, so the shard's
    mirror replay has not advanced — the §3.4 contract degenerates to "the
    poll never happened" and redelivery is automatic.  Everything else (DLQ,
    partition routing, lag…) passes through, so the wrapper satisfies
    whatever store protocol the inner one does — including
    ``ShardedWorkerPool``'s ``consume_partitions`` check.
    """

    def __init__(self, inner: Any, plan: FaultPlan) -> None:
        self._inner = inner
        self._plan = plan

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    def publish(self, workflow: str, event) -> None:
        self._plan.check("store.publish", event.id)
        return self._inner.publish(workflow, event)

    def publish_batch(self, workflow: str, events) -> None:
        self._plan.check("store.publish", _batch_key(events))
        return self._inner.publish_batch(workflow, events)

    def commit(self, workflow: str, event_ids) -> None:
        self._plan.check("store.commit", _batch_key(event_ids))
        return self._inner.commit(workflow, event_ids)

    def commit_partitions(self, workflow: str, partitions, event_ids) -> None:
        self._plan.check("store.commit", _batch_key(event_ids))
        return self._inner.commit_partitions(workflow, partitions, event_ids)

    # consume seam: the fault fires BEFORE the inner call, so no mirror
    # offset has advanced — the poll simply failed, and the next one sees
    # exactly the events this one would have.  Keyed by workflow+partitions
    # (a consume has no stable event identity before it returns).
    def consume(self, workflow: str, max_events: int = 512):
        self._plan.check("store.consume", workflow)
        return self._inner.consume(workflow, max_events)

    def consume_partition(self, workflow: str, partition: int,
                          max_events: int = 512):
        self._plan.check("store.consume", f"{workflow}:{partition}")
        return self._inner.consume_partition(workflow, partition, max_events)

    def consume_partitions(self, workflow: str, partitions,
                           max_events: int = 512):
        parts = list(partitions)
        self._plan.check(
            "store.consume",
            f"{workflow}:{','.join(str(p) for p in parts)}")
        return self._inner.consume_partitions(workflow, parts, max_events)


class ChaosStateStore:
    """Wraps any state store; injects at the checkpoint seam."""

    def __init__(self, inner: Any, plan: FaultPlan) -> None:
        self._inner = inner
        self._plan = plan

    def __getattr__(self, name: str) -> Any:
        return getattr(self._inner, name)

    def put_contexts_delta(self, workflow: str,
                           deltas: Dict[str, Dict[str, Any]]) -> None:
        self._plan.check("state.checkpoint", ":".join(sorted(deltas)))
        return self._inner.put_contexts_delta(workflow, deltas)


#: A torn *binary* record: the varint length prefix promises a 64-byte
#: payload but the crash left only a crc fragment and a few payload bytes.
#: ``codec.iter_records`` must refuse to advance past it.
TORN_BINARY_RECORD = b"\x40\xde\xad\xbe\xef\x00Ctorn"


def tear_segment_tail(root: str, suffix: str = ".log",
                      garbage: bytes = b'{"id":"torn-tail","su') -> List[str]:
    """Append a torn (half-written) record to every segment file under
    ``root`` — the on-disk state a crash mid-append leaves behind.  The
    tear matches each file's wire format (sniffed per file, like
    ``SegmentLog`` itself): a TFB1 segment gets a binary record cut
    mid-payload, a text segment the truncated-JSON ``garbage``.  Readers
    must stop before the torn record and the next locked writer must
    truncate it.  Returns the files torn."""
    from ..core import codec

    torn: List[str] = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in files:
            if not fname.endswith(suffix):
                continue
            path = os.path.join(dirpath, fname)
            with open(path, "ab+") as f:
                f.seek(0)
                head = f.read(len(codec.MAGIC))
                f.write(TORN_BINARY_RECORD if head == codec.MAGIC
                        else garbage)
            torn.append(path)
    return torn
