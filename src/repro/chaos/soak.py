"""Seeded chaos soak: a fan-out workflow under deterministic faults.

Shape of the workload (the paper's aggregation pattern, §5.2, with the
failure-policy plane turned on):

* ``n_root`` root events on subject ``fan``; the ``chaos_fanout`` action
  produces one child per root with a *deterministic id* (``kid-<i>`` or
  ``poison-<i>`` every ``poison_every``-th root), routed over ``n_subj``
  subjects.
* One recording trigger per subject runs ``chaos_record`` under a
  ``RetryPolicy``: the action deterministically fails its first
  ``k(seed, id)`` attempts (flaky), always fails for ``poison-*`` ids, and
  on success records the event *exactly once* into durable context
  (idempotent-by-id — the same dedup discipline the built-in
  ``exactly_once`` counter uses, which is what makes the results exact
  under at-least-once redelivery).

``run_soak`` (thread runtime) drives a ``ShardedWorkerPool`` whose stores
are wrapped in ``ChaosEventStore``/``ChaosStateStore``: publish, commit and
checkpoint calls fail on a seeded schedule, and each ``InjectedFault`` that
escapes a batch crashes the shard (``crash_shard``: the in-flight batch
discards its commit) before a replacement is added.  The drive loop is
single-threaded and every retry backoff is zero, so the whole run — fault
schedule, crash points, committed results — is a pure function of the seed:
``run_soak(seed=s)`` twice returns identical summaries, history included.

``run_soak_proc`` (process runtime) runs the same workload on a real
``ProcessShardPool`` with seeded SIGKILL points and an optional torn
segment tail between kill and restart.  OS scheduling makes the interleaving
(and therefore the history) machine-dependent there, so it asserts the
*invariants* only: every child recorded exactly once at its deterministic
attempt number, quarantine bounded at exactly the poison set, no committed
id duplicated, lag zero.
"""
from __future__ import annotations

import time
import zlib
from typing import Any, Dict, List, Optional

from ..core.actions import register_action
from ..core.events import CloudEvent
from ..core.policy import RETRY_STATE_KEY, REASON_ACTION_ERROR
from ..core.triggers import make_trigger
from .faults import ChaosEventStore, ChaosStateStore, FaultPlan, InjectedFault, \
    tear_segment_tail

WORKFLOW = "chaos-soak"

# Seeded store-seam fault rates for the thread soak; every seam is capped so
# the run provably terminates (a fault consumes budget, budgets are finite).
DEFAULT_RATES = {"store.publish": 0.12, "store.commit": 0.10,
                 "state.checkpoint": 0.08, "store.consume": 0.05}
DEFAULT_MAX_FAULTS = {"store.publish": 6, "store.commit": 5,
                      "state.checkpoint": 4, "store.consume": 3}

# The replicated soak adds the host-loss fault domain's seams: dropped
# replication frames/acks (healed, never crashing) and injected lease-expiry
# clock skew (a loud FencedWrite, cleared only by sanctioned re-assignment).
REPLICATED_RATES = dict(DEFAULT_RATES, **{
    "replicate.send": 0.08, "replicate.ack": 0.06, "lease.expire": 0.04})
REPLICATED_MAX_FAULTS = dict(DEFAULT_MAX_FAULTS, **{
    "replicate.send": 4, "replicate.ack": 3, "lease.expire": 2})


def _u(seed: int, *parts: Any) -> float:
    h = zlib.crc32(":".join(str(p) for p in (seed,) + parts).encode())
    return h / 2 ** 32


def fail_budget(seed: int, event_id: str, fail_pct: int,
                max_consecutive: int = 2) -> int:
    """How many leading attempts of ``event_id`` fail (0 = never flaky).
    Pure function of (seed, id): every delivery — original, retry, or
    post-crash replay — computes the same schedule."""
    h = zlib.crc32(f"{seed}:flaky:{event_id}".encode())
    if (h % 100) >= fail_pct:
        return 0
    return 1 + (h >> 8) % max_consecutive


def _attempt_number(ctx, event) -> int:
    """This delivery's 1-based attempt number, from the durable retry state
    (the policy plane records attempt N *after* attempt N fails)."""
    rec = (ctx.get(RETRY_STATE_KEY) or {}).get(event.id)
    return (rec[0] if rec else 0) + 1


def _chaos_fanout(ctx, event, params) -> None:
    """Produce one deterministic-id child per root event (§5.2 fan-out).
    Child ids are stable across runs and replays, so chaos decisions keyed
    on them — and the final committed id set — are seed-reproducible."""
    i = event.data["i"]
    poison_every = params.get("poison_every", 0)
    poison = poison_every and i % poison_every == 0
    kid = CloudEvent(
        subject="s%d" % (i % params["n_subj"]),
        data={"result": i},
        id=("poison-%d" % i) if poison else ("kid-%d" % i))
    ctx.produce(kid)


def _chaos_record(ctx, event, params) -> None:
    """Deterministically flaky recorder: fail the first ``k(seed, id)``
    attempts, always fail poison ids, then record exactly once by id."""
    if event.id.startswith("poison-"):
        raise InjectedFault("poison event %s" % event.id)
    attempt = _attempt_number(ctx, event)
    k = fail_budget(params["seed"], event.id, params.get("fail_pct", 0),
                    params.get("max_consecutive", 2))
    if attempt <= k:
        raise InjectedFault(
            "flaky %s attempt %d/%d" % (event.id, attempt, k))
    done = dict(ctx.get("done") or {})
    if event.id not in done:  # idempotent by id: exact under redelivery
        done[event.id] = attempt
        ctx["done"] = done


def register_soak_functions() -> None:
    register_action("chaos_fanout", _chaos_fanout)
    register_action("chaos_record", _chaos_record)


register_soak_functions()


def soak_child_init(backend) -> None:
    """`child_init` for spawn-started shard processes: importing this module
    registers the chaos actions (fork children inherit them for free)."""
    register_soak_functions()


def _soak_triggers(seed: int, n_subj: int, poison_every: int, fail_pct: int,
                   max_attempts: int = 4):
    # zero backoff + zero jitter: retries re-enter on the very next batch, so
    # the thread soak's schedule is timing-independent (seed-deterministic)
    policy = {"max_attempts": max_attempts, "backoff_base": 0.0,
              "backoff_factor": 1.0, "backoff_max": 0.0, "jitter": 0.0}
    trgs = [make_trigger(
        "fan", condition={"name": "true"},
        action={"name": "chaos_fanout", "n_subj": n_subj,
                "poison_every": poison_every},
        trigger_id="t-fan", transient=False, retry=policy)]
    for j in range(n_subj):
        trgs.append(make_trigger(
            f"s{j}", condition={"name": "true"},
            action={"name": "chaos_record", "seed": seed,
                    "fail_pct": fail_pct, "max_consecutive": 2},
            trigger_id=f"t-rec-{j}", transient=False, retry=policy))
    return trgs


def expected_results(seed: int, n_root: int, n_subj: int, poison_every: int,
                     fail_pct: int) -> Dict[str, Dict[str, int]]:
    """The oracle: per-subject ``{kid id: success attempt}`` maps."""
    out: Dict[str, Dict[str, int]] = {f"s{j}": {} for j in range(n_subj)}
    for i in range(n_root):
        if poison_every and i % poison_every == 0:
            continue
        kid = "kid-%d" % i
        out["s%d" % (i % n_subj)][kid] = 1 + fail_budget(seed, kid, fail_pct)
    return out


def n_poison(n_root: int, poison_every: int) -> int:
    if not poison_every:
        return 0
    return len(range(0, n_root, poison_every))


def assert_invariants(summary: Dict[str, Any], seed: int, n_root: int,
                      n_subj: int, poison_every: int, fail_pct: int) -> None:
    """The soak's acceptance bar — exactly-once results, bounded quarantine,
    nothing stuck — shared by both runtimes."""
    assert summary["lag"] == 0, f"stuck partitions: {summary}"
    oracle = expected_results(seed, n_root, n_subj, poison_every, fail_pct)
    assert summary["done"] == oracle, (
        f"committed results drifted from the oracle:\n"
        f"  got      {summary['done']}\n  expected {oracle}")
    poison = n_poison(n_root, poison_every)
    want_dlq = {REASON_ACTION_ERROR: poison} if poison else {}
    assert summary["dlq_by_reason"] == want_dlq, (
        f"quarantine not bounded at the poison set: {summary['dlq_by_reason']}"
        f" != {want_dlq}")
    ids = summary["committed_ids"]
    assert len(ids) == len(set(ids)), "an event id committed twice"
    missing = {f"soak-{i}" for i in range(n_root)} - set(ids)
    assert not missing, f"root events never committed: {sorted(missing)}"


def _lose_tree(path: str, timeout: float = 5.0) -> None:
    """rmtree that tolerates racing writers — the host-loss simulation.

    A zombie shard may recreate a file between rmtree's directory scan and
    the final rmdir (Errno 39).  It can only win that race a bounded number
    of times: its next commit reads the missing lease, fences, and exits.
    """
    import shutil
    deadline = time.monotonic() + timeout
    while True:
        try:
            shutil.rmtree(path)
            return
        except FileNotFoundError:
            return
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.01)


def _collect(pool, store, n_subj: int) -> Dict[str, Any]:
    done = {}
    for j in range(n_subj):
        ctx = pool.trigger_context(WORKFLOW, f"t-rec-{j}")
        done[f"s{j}"] = dict(ctx.get("done") or {})
    return {
        "done": done,
        "dlq_by_reason": store.dlq_by_reason(WORKFLOW),
        "committed_ids": sorted(e.id for e in store.committed_events(WORKFLOW)),
        "lag": store.lag(WORKFLOW),
        "obs": pool.obs_snapshot(WORKFLOW)["counters"],
    }


def run_soak(seed: int = 0, n_root: int = 39, n_subj: int = 4,
             poison_every: int = 13, fail_pct: int = 35, shards: int = 2,
             rates: Optional[Dict[str, float]] = None,
             max_faults: Optional[Dict[str, int]] = None,
             batch_size: int = 16, timeout: float = 30.0,
             tracer=None) -> Dict[str, Any]:
    """Thread-runtime soak: deterministic drive under seeded store faults.

    Returns a summary (already asserted against the oracle) whose every
    field — including the fault ``history`` — is a pure function of the
    arguments: run it twice with one seed and compare.
    """
    from ..bus import PartitionedEventStore, ShardedWorkerPool
    from ..core.functions import FunctionBackend
    from ..core.statestore import MemoryStateStore

    plan = FaultPlan(seed,
                     rates if rates is not None else DEFAULT_RATES,
                     max_faults if max_faults is not None else DEFAULT_MAX_FAULTS)
    inner = PartitionedEventStore(n_subj)
    store = ChaosEventStore(inner, plan)
    state = ChaosStateStore(MemoryStateStore(), plan)
    pool = ShardedWorkerPool(
        store, state, FunctionBackend(store, inline=True),
        commit_policy="every_batch", batch_size=batch_size,
        keep_event_log=False, tracer=tracer)
    for trg in _soak_triggers(seed, n_subj, poison_every, fail_pct):
        pool.add_trigger(WORKFLOW, trg)
    inner.publish_batch(WORKFLOW, [
        CloudEvent(subject="fan", data={"i": i}, id=f"soak-{i}")
        for i in range(n_root)])
    pool.set_shard_count(WORKFLOW, shards)

    deadline = time.monotonic() + timeout
    crashes = 0
    while True:
        progressed = 0
        for member in pool.shard_ids(WORKFLOW):
            try:
                progressed += pool.run_shard_once(WORKFLOW, member)
            except InjectedFault:
                # the batch's checkpoint/commit (or a mid-fire publish that
                # escaped the retry budget) tore: treat it as a shard crash —
                # discard the in-flight commit, rebalance, replay
                pool.crash_shard(WORKFLOW, member)
                crashes += 1
        if pool.shard_count(WORKFLOW) < shards:
            pool.set_shard_count(WORKFLOW, shards)
            continue
        if progressed == 0 and inner.lag(WORKFLOW) == 0:
            break
        if time.monotonic() > deadline:
            raise TimeoutError("chaos soak did not drain: "
                               + pool.failure_diagnostics(WORKFLOW))

    summary = _collect(pool, inner, n_subj)
    summary["faults"] = plan.faults_injected()
    summary["history"] = list(plan.history)
    summary["crashes"] = crashes
    assert_invariants(summary, seed, n_root, n_subj, poison_every, fail_pct)
    return summary


def run_soak_proc(root: str, seed: int = 0, n_root: int = 24, n_subj: int = 4,
                  poison_every: int = 9, fail_pct: int = 30, shards: int = 2,
                  kills: int = 2, torn_tail: bool = True,
                  batch_size: int = 16, timeout: float = 90.0,
                  fsync: bool = True) -> Dict[str, Any]:
    """Process-runtime soak: the same workload over the durable file bus
    with seeded SIGKILL points (and a torn segment tail after the first
    kill).  Asserts the shared invariants; interleaving-dependent fields
    (history) do not exist here."""
    from ..bus import ProcessShardPool

    pool = ProcessShardPool(
        root, num_partitions=n_subj, batch_size=batch_size, fsync=fsync,
        child_init=soak_child_init,
        # soften the breaker so deliberate kills never stall the restart
        # schedule past the soak timeout (the kills are the test, not a
        # genuine crash loop)
        breaker={"backoff_base": 0.02, "backoff_max": 0.1, "cooldown": 0.05})
    try:
        pool.create_workflow(WORKFLOW)
        for trg in _soak_triggers(seed, n_subj, poison_every, fail_pct):
            pool.add_trigger(WORKFLOW, trg)
        pool.publish_batch(WORKFLOW, [
            CloudEvent(subject="fan", data={"i": i}, id=f"soak-{i}")
            for i in range(n_root)])
        pool.start_shards(WORKFLOW, shards)

        # Seeded kill-at-point schedule: each kill waits for a seed-chosen
        # share of the final commit volume, SIGKILLs a seed-chosen victim,
        # optionally tears a segment tail, then restarts capacity.
        total_commits = n_root + (n_root - n_poison(n_root, poison_every))
        deadline = time.monotonic() + timeout
        for k in range(kills):
            u = _u(seed, "kill", k)
            target = int(total_commits * (0.15 + 0.6 * u) * (k + 1) / kills)
            while (sum(pool.event_store.commit_offsets(WORKFLOW)) < target
                   and pool.lag(WORKFLOW) > 0):
                if time.monotonic() > deadline:
                    raise TimeoutError("soak never reached kill point %d: %s"
                                       % (k, pool.failure_diagnostics(WORKFLOW)))
                time.sleep(0.002)
            members = pool.shard_ids(WORKFLOW)
            if not members:
                pool.start_shards(WORKFLOW, shards)
                continue
            pool.crash_shard(WORKFLOW, members[int(u * len(members)) % len(members)])
            if torn_tail and k == 0:
                tear_segment_tail(pool.bus_root, suffix=".log")
            pool.start_shards(WORKFLOW, shards)
        pool.wait_drained(WORKFLOW, timeout=max(5.0, deadline - time.monotonic()))

        summary = _collect(pool, pool.event_store, n_subj)
        summary["crashes"] = pool.metrics(WORKFLOW)["crashes"]
        assert_invariants(summary, seed, n_root, n_subj, poison_every, fail_pct)
        return summary
    finally:
        pool.stop_all()


def _files_equal(a_dir: str, b_dir: str, skip=("pub.notify",)) -> List[str]:
    """Names under ``a_dir`` whose bytes differ from (or are missing in)
    ``b_dir``.  Empty list ⇒ the replica truly mirrors the primary."""
    import os
    diff: List[str] = []
    for fn in sorted(os.listdir(a_dir)):
        if fn in skip or not os.path.isfile(os.path.join(a_dir, fn)):
            continue
        a = os.path.join(a_dir, fn)
        b = os.path.join(b_dir, fn)
        try:
            with open(a, "rb") as fa, open(b, "rb") as fb:
                if fa.read() != fb.read():
                    diff.append(fn)
        except OSError:
            diff.append(fn)
    return diff


def run_soak_replicated(root: str, seed: int = 0, n_root: int = 30,
                        n_subj: int = 4, poison_every: int = 11,
                        fail_pct: int = 30, shards: int = 2,
                        rates: Optional[Dict[str, float]] = None,
                        max_faults: Optional[Dict[str, int]] = None,
                        batch_size: int = 16,
                        timeout: float = 60.0) -> Dict[str, Any]:
    """Thread-runtime soak over the *replicated, lease-fenced* file bus.

    Same deterministic drive as ``run_soak`` — plus the host-loss fault
    domain's seams: replication frames/acks drop on the seeded schedule
    (healed, never crashing a writer), lease-expiry clock skew fences owner
    writes (``FencedWrite`` crashes the shard; the replacement's rebalance
    re-acquires with a bumped epoch), and at a seed-chosen commit volume the
    primary's segment root is DELETED and rebuilt from the replica
    (``restore_from_replica``), after which the run resumes exactly-once.

    Every field of the summary — fault history, fence count, the recovery
    point — is a pure function of the arguments; the determinism test runs
    it twice and compares.  Before the loss the replica is healed to lag
    zero (semi-sync replication's acked offset IS the recovery point; the
    in-flight-lag data-loss window is pinned by the transport tests, not
    here, so the oracle stays exact for every seed).
    """
    import os

    from ..bus import FencedWrite, ReplicaServer, ShardedWorkerPool
    from ..bus.partitioned import FilePartitionedEventStore
    from ..core.functions import FunctionBackend
    from ..core.statestore import MemoryStateStore

    plan = FaultPlan(
        seed,
        rates if rates is not None else REPLICATED_RATES,
        max_faults if max_faults is not None else REPLICATED_MAX_FAULTS)
    replica_root = os.path.join(root, "replica")
    server = ReplicaServer(replica_root)
    inner = FilePartitionedEventStore(
        os.path.join(root, "bus"), n_subj, fsync=False,
        replicate_to=server.address, replicate_sync=True,
        lease_owner="node-a",
        lease_skew_hook=lambda wf, p: plan.decide(
            "lease.expire", f"{wf}:{p}"),
        replicate_fault_hook=plan.check)
    store = ChaosEventStore(inner, plan)
    state = ChaosStateStore(MemoryStateStore(), plan)
    pool = ShardedWorkerPool(
        store, state, FunctionBackend(store, inline=True),
        commit_policy="every_batch", batch_size=batch_size,
        keep_event_log=False)
    try:
        inner.create_stream(WORKFLOW)
        for trg in _soak_triggers(seed, n_subj, poison_every, fail_pct):
            pool.add_trigger(WORKFLOW, trg)
        inner.publish_batch(WORKFLOW, [
            CloudEvent(subject="fan", data={"i": i}, id=f"soak-{i}")
            for i in range(n_root)])
        pool.set_shard_count(WORKFLOW, shards)

        total_commits = n_root + (n_root - n_poison(n_root, poison_every))
        loss_at = int(total_commits * (0.2 + 0.5 * _u(seed, "host-loss")))
        deadline = time.monotonic() + timeout
        crashes = recoveries = 0
        lost = False
        while True:
            progressed = 0
            for member in pool.shard_ids(WORKFLOW):
                try:
                    progressed += pool.run_shard_once(WORKFLOW, member)
                except (InjectedFault, FencedWrite):
                    # an injected fault tore the batch, or the owner's lease
                    # was superseded/skew-expired mid-write: either way the
                    # shard dies loudly and the replacement replays
                    pool.crash_shard(WORKFLOW, member)
                    crashes += 1
            if not lost and \
                    sum(inner.commit_offsets(WORKFLOW)) >= loss_at:
                lost = True
                # heal the replica to lag zero (drop caps make this
                # converge), then lose the host: segment root deleted,
                # rebuilt from the replica, every worker replaced
                for _ in range(8):
                    inner.heal_replication(WORKFLOW)
                    inner.drain_replication(10.0)
                    if inner.replication_stats()["lag_bytes"] == 0:
                        break
                _lose_tree(inner._wf_dir(WORKFLOW))
                inner.restore_from_replica(WORKFLOW, replica_root)
                for member in pool.shard_ids(WORKFLOW):
                    pool.crash_shard(WORKFLOW, member)
                recoveries += 1
            if pool.shard_count(WORKFLOW) < shards:
                pool.set_shard_count(WORKFLOW, shards)
                continue
            if progressed == 0 and inner.lag(WORKFLOW) == 0:
                break
            if time.monotonic() > deadline:
                raise TimeoutError("replicated chaos soak did not drain: "
                                   + pool.failure_diagnostics(WORKFLOW))

        # final reconcile: the replica must end byte-identical to the
        # primary (modulo the advisory notify/lease/meta files)
        for _ in range(8):
            inner.heal_replication(WORKFLOW)
            inner.drain_replication(10.0)
            if inner.replication_stats()["lag_bytes"] == 0:
                break
        wf_dirname = WORKFLOW.replace("/", "_")
        diverged = [
            fn for fn in _files_equal(
                inner._wf_dir(WORKFLOW),
                os.path.join(replica_root, wf_dirname))
            if fn.rpartition(".")[2] in ("log", "committed", "dlq")]
        assert not diverged, f"replica diverged from primary: {diverged}"

        summary = _collect(pool, inner, n_subj)
        summary["faults"] = plan.faults_injected()
        summary["history"] = list(plan.history)
        summary["crashes"] = crashes
        summary["fenced"] = inner.fenced_writes
        summary["dropped_frames"] = inner._rep.dropped if inner._rep else 0
        summary["recoveries"] = recoveries
        assert recoveries == 1, "the host-loss point never fired"
        assert_invariants(summary, seed, n_root, n_subj, poison_every,
                          fail_pct)
        return summary
    finally:
        if inner._rep is not None:
            inner._rep.close()
        server.close()


def run_soak_host_loss(root: str, seed: int = 0, n_root: int = 24,
                       n_subj: int = 4, poison_every: int = 9,
                       fail_pct: int = 30, shards: int = 2,
                       batch_size: int = 16, timeout: float = 120.0,
                       recovery_bound: float = 15.0,
                       fsync: bool = False) -> Dict[str, Any]:
    """Process-runtime host-loss soak: run the chaos workload on a
    replicated, lease-fenced ``ProcessShardPool``; at a seed-chosen commit
    volume DELETE the workflow's segment root out from under the live shard
    processes (unlinked inodes: the nastiest version of losing the disk),
    then ``recover_host_loss`` — SIGKILL the zombies, rehydrate from the
    replica, restart with bumped lease epochs — and drain to the exact
    oracle.  Asserts recovery lands under ``recovery_bound`` seconds."""
    import os

    from ..bus import ProcessShardPool

    pool = ProcessShardPool(
        root, num_partitions=n_subj, batch_size=batch_size, fsync=fsync,
        child_init=soak_child_init, replicate=True, lease=True,
        breaker={"backoff_base": 0.02, "backoff_max": 0.1, "cooldown": 0.05})
    try:
        pool.create_workflow(WORKFLOW)
        for trg in _soak_triggers(seed, n_subj, poison_every, fail_pct):
            pool.add_trigger(WORKFLOW, trg)
        pool.publish_batch(WORKFLOW, [
            CloudEvent(subject="fan", data={"i": i}, id=f"soak-{i}")
            for i in range(n_root)])
        pool.start_shards(WORKFLOW, shards)

        total_commits = n_root + (n_root - n_poison(n_root, poison_every))
        target = int(total_commits * (0.2 + 0.5 * _u(seed, "host-loss")))
        deadline = time.monotonic() + timeout
        while (sum(pool.event_store.commit_offsets(WORKFLOW)) < target
               and pool.lag(WORKFLOW) > 0):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    "host-loss soak never reached the loss point: "
                    + pool.failure_diagnostics(WORKFLOW))
            time.sleep(0.002)

        _lose_tree(os.path.join(
            pool.bus_root, WORKFLOW.replace("/", "_")))
        recovery_seconds = pool.recover_host_loss(WORKFLOW, count=shards)
        assert recovery_seconds < recovery_bound, (
            f"recovery took {recovery_seconds:.2f}s "
            f"(bound {recovery_bound}s)")

        pool.wait_drained(
            WORKFLOW, timeout=max(5.0, deadline - time.monotonic()))
        summary = _collect(pool, pool.event_store, n_subj)
        m = pool.metrics(WORKFLOW)
        summary["crashes"] = m["crashes"]
        summary["recoveries"] = m["node_recoveries"]
        summary["recovery_seconds"] = recovery_seconds
        summary["leases"] = pool.event_store.lease_holders(WORKFLOW)
        assert summary["recoveries"] == 1
        assert summary["obs"].get("tf_node_recoveries_total") == 1
        assert_invariants(summary, seed, n_root, n_subj, poison_every,
                          fail_pct)
        return summary
    finally:
        pool.stop_all()
        pool.close_replication()
