# Deterministic fault injection for the failure-policy plane: seeded,
# replayable fault schedules over the runtime's real seams (publish, commit,
# consume, checkpoint, torn segment tails, SIGKILL points, dropped
# replication frames/acks, lease-expiry skew, host loss) plus the soaks that
# drive a fan-out workflow through them on both shard runtimes.
from .faults import (ChaosEventStore, ChaosStateStore, FaultPlan,
                     InjectedFault, tear_segment_tail)
from .soak import (assert_invariants, expected_results, fail_budget,
                   run_soak, run_soak_host_loss, run_soak_proc,
                   run_soak_replicated, soak_child_init)

__all__ = [
    "ChaosEventStore",
    "ChaosStateStore",
    "FaultPlan",
    "InjectedFault",
    "assert_invariants",
    "expected_results",
    "fail_budget",
    "run_soak",
    "run_soak_host_loss",
    "run_soak_proc",
    "run_soak_replicated",
    "soak_child_init",
    "tear_segment_tail",
]
