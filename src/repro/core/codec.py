"""One event codec for the whole bus: TFB1 binary record framing, the
columnar batch frame, and the single CloudEvent (de)serialization
implementation.

Three layers, bottom-up:

* **Record framing** — ``encode_record`` / ``scan_records``.  A record is
  ``varint(len(payload)) + crc32(payload) + payload``; a segment file in
  binary mode starts with the 5-byte ``MAGIC`` (``TFB1\\x00`` — the NUL
  guarantees no collision with a JSON/text v1 line).  ``scan_records``
  consumes only whole, crc-valid records and reports the byte offset
  after the last one, so a torn tail (truncation at *any* byte offset)
  is recovered as exactly the prefix of whole records: a cut payload
  fails the length check, a cut length/crc header fails the varint or
  bounds check, and a corrupted payload fails crc.

* **Columnar frames** — ``encode_frame_payload`` packs a batch of events
  into one payload holding *columns* (one interned string table for
  subject/type/source/specversion, index arrays, an id blob, tagged
  time/data/ext columns) instead of per-event dicts.
  ``decode_frame_payload`` returns an :class:`EventColumns` view whose
  columns feed ``VectorJoinPlane.triage`` directly; per-event
  ``CloudEvent`` objects are materialized lazily and only when a
  consumer actually needs them.  The payload's first byte is NUL
  (``FRAME_TAG``) so ``decode_payload`` can tell a columnar frame from
  a JSON payload without trying to parse it.

* **Event codec** — ``event_to_dict`` / ``event_from_dict`` /
  ``event_to_json`` / ``event_from_json`` are the *only* encode and
  decode implementations for ``CloudEvent``; ``repro.core.events`` binds
  them as the class's methods at import time via :func:`_install`
  (codec never imports events — that would be circular).
"""
from __future__ import annotations

import json
import struct
import sys
import zlib
from array import array
from typing import Any, Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------------------
# record framing

MAGIC = b"TFB1\x00"
FRAME_TAG = b"\x00C"  # columnar frame payloads start with NUL + 'C'

_CRC = struct.Struct("<I")


def encode_varint(n: int) -> bytes:
    """LEB128 unsigned varint."""
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _decode_varint(buf: bytes, o: int, end: int) -> Tuple[Optional[int], int]:
    """Decode one varint at ``o``; ``(None, o)`` if torn or overlong."""
    shift = 0
    n = 0
    start = o
    while o < end:
        b = buf[o]
        o += 1
        n |= (b & 0x7F) << shift
        if not (b & 0x80):
            return n, o
        shift += 7
        if shift > 35:  # >5 bytes cannot be a sane record length
            return None, start
    return None, start


def encode_record(payload: bytes) -> bytes:
    """Frame one payload: varint length + crc32 + payload bytes."""
    return (encode_varint(len(payload))
            + _CRC.pack(zlib.crc32(payload) & 0xFFFFFFFF) + payload)


def encode_records(payloads: Iterable[bytes]) -> bytes:
    return b"".join(encode_record(p) for p in payloads)


def iter_records(buf: bytes, offset: int = 0):
    """Yield ``(payload, end_offset)`` for each whole crc-valid record
    from ``offset``; stop (without advancing) at the first torn or
    corrupt record — a cut payload fails the bounds check, a cut
    length/crc header fails the varint or bounds check, a flipped byte
    fails crc."""
    o = offset
    end = len(buf)
    while o < end:
        n, h = _decode_varint(buf, o, end)
        if n is None or h + 4 + n > end:
            return
        payload = buf[h + 4:h + 4 + n]
        if zlib.crc32(payload) & 0xFFFFFFFF != _CRC.unpack_from(buf, h)[0]:
            return
        o = h + 4 + n
        yield payload, o


def scan_records(buf: bytes, offset: int = 0) -> Tuple[List[bytes], int]:
    """Consume whole valid records from ``offset``.

    Returns ``(payloads, valid_end)`` where ``valid_end`` is the offset
    just past the last whole crc-valid record.  Stops (without
    advancing) at the first torn or corrupt record, mirroring the
    text-mode torn-tail contract of ``SegmentLog.scan``.
    """
    payloads: List[bytes] = []
    o = offset
    for payload, o in iter_records(buf, offset):
        payloads.append(payload)
    return payloads, o


# ---------------------------------------------------------------------------
# the one CloudEvent (de)serialization implementation
#
# ``repro.core.events`` calls ``_install(CloudEvent)`` at import time and
# binds these functions as the class's to_dict/to_json/from_dict/from_json,
# so every surface — per-event, batch line, columnar frame — shares exactly
# one encode and one decode.

_CloudEvent: Any = None
_TYPE_DEFAULT = "event.triggerflow.termination.success"
_SOURCE_DEFAULT = "triggerflow"
_SPECVERSION = "1.0"


def _install(cls: type) -> None:
    global _CloudEvent, _TYPE_DEFAULT, _SOURCE_DEFAULT, _SPECVERSION
    _CloudEvent = cls
    fields = cls.__dataclass_fields__
    _TYPE_DEFAULT = fields["type"].default
    _SOURCE_DEFAULT = fields["source"].default
    _SPECVERSION = fields["specversion"].default


def event_to_dict(ev) -> Dict[str, Any]:
    d = {
        "specversion": ev.specversion,
        "id": ev.id,
        "source": ev.source,
        "subject": ev.subject,
        "type": ev.type,
        "time": ev.time,
        "data": ev.data,
    }
    if ev.ext is not None:
        d["ext"] = ev.ext
    return d


def event_to_json(ev) -> str:
    return json.dumps(event_to_dict(ev), separators=(",", ":"))


def event_from_dict(d: Dict[str, Any]):
    # Deserialization is the file-bus consumer's per-event floor, so it
    # bypasses the frozen-dataclass __init__ (~4x): build the instance
    # directly in __dict__ (writes don't go through __setattr__).
    ev = object.__new__(_CloudEvent)
    ev.__dict__.update({
        "subject": d["subject"],
        "type": d.get("type", _TYPE_DEFAULT),
        "data": d.get("data"),
        "source": d.get("source", _SOURCE_DEFAULT),
        "id": d["id"],
        "time": d.get("time"),
        "specversion": d.get("specversion", _SPECVERSION),
        "ext": d.get("ext"),
    })
    return ev


def event_from_json(s: str):
    return event_from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# columnar frames

_SEP = "\x1f"
_HDR = struct.Struct("<I")
_F64 = struct.Struct("<d")

# time column tags
_T_NONE = 0      # every event's time is None
_T_SAME = 1      # one shared float (the common stamp_publish_time batch)
_T_JSON = 2      # JSON list fallback (mixed / per-event times)
# data column tags
_D_RESULT = 1    # every data is exactly {"result": v}: store the v scalars
_D_JSON = 2      # JSON list of the raw data objects
# id blob tags
_I_SEP = 0       # \x1f-joined utf-8 (no id contains \x1f)
_I_JSON = 1      # JSON list fallback
# ext column tags
_E_NONE = 0      # every ext is None (the common untraced batch)
_E_JSON = 1      # JSON list of ext dicts / nulls


def _pack_str(s: bytes) -> bytes:
    return encode_varint(len(s)) + s


class _Cursor:
    __slots__ = ("buf", "o")

    def __init__(self, buf: bytes, o: int):
        self.buf = buf
        self.o = o

    def varint(self) -> int:
        n, self.o = _decode_varint(self.buf, self.o, len(self.buf))
        if n is None:
            raise ValueError("torn frame varint")
        return n

    def take(self, n: int) -> bytes:
        b = self.buf[self.o:self.o + n]
        if len(b) != n:
            raise ValueError("torn frame blob")
        self.o += n
        return b

    def byte(self) -> int:
        if self.o >= len(self.buf):
            raise ValueError("torn frame byte")
        b = self.buf[self.o]
        self.o += 1
        return b


def encode_frame_payload(events) -> bytes:
    """Pack a batch of CloudEvents into one columnar frame payload."""
    n = len(events)
    parts: List[bytes] = [FRAME_TAG, encode_varint(n)]
    if n == 0:
        return b"".join(parts)

    # one interned string table for the four low-cardinality columns
    table: Dict[Any, int] = {}

    def intern(s) -> int:
        i = table.get(s)
        if i is None:
            i = table[s] = len(table)
        return i

    subj = [intern(e.subject) for e in events]
    typ = [intern(e.type) for e in events]
    src = [intern(e.source) for e in events]
    spec = [intern(e.specversion) for e in events]
    tab_blob = json.dumps(list(table), separators=(",", ":")).encode("utf-8")
    parts.append(_pack_str(tab_blob))

    if len(table) <= 0xFF:
        parts.append(b"\x01")
        parts.append(bytes(subj))
        parts.append(bytes(typ))
        parts.append(bytes(src))
        parts.append(bytes(spec))
    else:
        # 2-byte indices up to 65535 interned strings, 4-byte beyond —
        # a pathological batch with huge subject/type cardinality still
        # encodes instead of overflowing array("H")
        code = "H" if len(table) <= 0xFFFF else "I"
        parts.append(b"\x02" if code == "H" else b"\x04")
        for col in (subj, typ, src, spec):
            a = array(code, col)
            if sys.byteorder != "little":
                a.byteswap()
            parts.append(a.tobytes())

    ids = [e.id for e in events]
    if any(type(i) is not str or _SEP in i for i in ids):
        parts.append(bytes((_I_JSON,)))
        parts.append(_pack_str(
            json.dumps(ids, separators=(",", ":")).encode("utf-8")))
    else:
        parts.append(bytes((_I_SEP,)))
        parts.append(_pack_str(_SEP.join(ids).encode("utf-8")))

    t0 = events[0].time
    if all(e.time is None for e in events):
        parts.append(bytes((_T_NONE,)))
    elif type(t0) is float and all(e.time == t0 for e in events):
        parts.append(bytes((_T_SAME,)))
        parts.append(_F64.pack(t0))
    else:
        parts.append(bytes((_T_JSON,)))
        parts.append(_pack_str(json.dumps(
            [e.time for e in events], separators=(",", ":")).encode("utf-8")))

    results: List[Any] = []
    for e in events:
        data = e.data
        if type(data) is dict and len(data) == 1 and "result" in data:
            results.append(data["result"])
        else:
            results = None  # type: ignore[assignment]
            break
    if results is not None:
        parts.append(bytes((_D_RESULT,)))
        parts.append(_pack_str(
            json.dumps(results, separators=(",", ":")).encode("utf-8")))
    else:
        parts.append(bytes((_D_JSON,)))
        parts.append(_pack_str(json.dumps(
            [e.data for e in events], separators=(",", ":")).encode("utf-8")))

    if all(e.ext is None for e in events):
        parts.append(bytes((_E_NONE,)))
    else:
        parts.append(bytes((_E_JSON,)))
        parts.append(_pack_str(json.dumps(
            [e.ext for e in events], separators=(",", ":")).encode("utf-8")))

    return b"".join(parts)


def decode_frame_payload(payload: bytes) -> "EventColumns":
    """Decode one columnar frame payload into an :class:`EventColumns`."""
    if payload[:2] != FRAME_TAG:
        raise ValueError("not a columnar frame payload")
    cur = _Cursor(payload, 2)
    n = cur.varint()
    cols = EventColumns.__new__(EventColumns)
    if n == 0:
        cols._init_empty()
        return cols

    table = json.loads(cur.take(cur.varint()))
    width = cur.byte()
    if width == 1:
        subj_i: Any = cur.take(n)
        typ_i: Any = cur.take(n)
        src_i: Any = cur.take(n)
        spec_i: Any = cur.take(n)
    elif width in (2, 4):
        code = "H" if width == 2 else "I"

        def uint(blob: bytes) -> array:
            a = array(code)
            a.frombytes(blob)
            if sys.byteorder != "little":
                a.byteswap()
            return a
        subj_i = uint(cur.take(width * n))
        typ_i = uint(cur.take(width * n))
        src_i = uint(cur.take(width * n))
        spec_i = uint(cur.take(width * n))
    else:
        raise ValueError("unknown frame index width %d" % width)

    itag = cur.byte()
    blob = cur.take(cur.varint())
    if itag == _I_SEP:
        ids = blob.decode("utf-8").split(_SEP)
    else:
        ids = json.loads(blob)

    ttag = cur.byte()
    tval: Any = None
    if ttag == _T_SAME:
        tval = _F64.unpack(cur.take(8))[0]
    elif ttag == _T_JSON:
        tval = json.loads(cur.take(cur.varint()))

    dtag = cur.byte()
    data_col = json.loads(cur.take(cur.varint()))

    etag = cur.byte()
    ext_col = json.loads(cur.take(cur.varint())) if etag == _E_JSON else None

    cols.ids = ids
    cols.subjects = [table[i] for i in subj_i]
    cols.types = [table[i] for i in typ_i]
    cols.sources = [table[i] for i in src_i]
    cols.specversions = [table[i] for i in spec_i]
    cols._time_tag = ttag
    cols._time_val = tval
    cols._data_tag = dtag
    cols._data_col = data_col
    cols._ext_col = ext_col
    cols._events = None
    return cols


class EventColumns:
    """Columnar view over a decoded event batch.

    ``subjects`` / ``types`` / ``ids`` and :meth:`results` are plain
    parallel lists the counting planes consume directly — no per-event
    objects exist until :meth:`events` (or indexing) materializes them,
    and that materialization is cached.
    """

    __slots__ = ("ids", "subjects", "types", "sources", "specversions",
                 "_time_tag", "_time_val", "_data_tag", "_data_col",
                 "_ext_col", "_events")

    def __init__(self, events=None):
        if events is None:
            self._init_empty()
        else:
            self._init_from_events(list(events))

    def _init_empty(self) -> None:
        self.ids = []
        self.subjects = []
        self.types = []
        self.sources = []
        self.specversions = []
        self._time_tag = _T_NONE
        self._time_val = None
        self._data_tag = _D_JSON
        self._data_col = []
        self._ext_col = None
        self._events = []

    def _init_from_events(self, events) -> None:
        self.ids = [e.id for e in events]
        self.subjects = [e.subject for e in events]
        self.types = [e.type for e in events]
        self.sources = [e.source for e in events]
        self.specversions = [e.specversion for e in events]
        self._time_tag = _T_JSON
        self._time_val = [e.time for e in events]
        self._data_tag = _D_JSON
        self._data_col = [e.data for e in events]
        exts = [e.ext for e in events]
        self._ext_col = exts if any(x is not None for x in exts) else None
        self._events = events

    @classmethod
    def from_events(cls, events) -> "EventColumns":
        if isinstance(events, cls):
            return events
        return cls(events)

    def __len__(self) -> int:
        return len(self.ids)

    def results(self) -> List[Any]:
        """Per-event result values, matching ``conditions._result_of``:
        ``data["result"]`` when data is a dict carrying one, else data
        itself.  Always a fresh list the caller owns — on a ``_D_RESULT``
        frame a flat copy of the stored scalar column (no per-event work;
        handing out the cached column by reference would let a mutating
        caller corrupt what ``data_at``/``events`` later read)."""
        if self._data_tag == _D_RESULT:
            return list(self._data_col)
        return [d["result"] if isinstance(d, dict) and "result" in d else d
                for d in self._data_col]

    def time_at(self, i: int):
        if self._time_tag == _T_NONE:
            return None
        if self._time_tag == _T_SAME:
            return self._time_val
        return self._time_val[i]

    def data_at(self, i: int):
        if self._data_tag == _D_RESULT:
            return {"result": self._data_col[i]}
        return self._data_col[i]

    def ext_at(self, i: int):
        return None if self._ext_col is None else self._ext_col[i]

    def events(self) -> list:
        """Materialize (once) the per-event CloudEvent objects."""
        if self._events is None:
            tag = self._data_tag
            data_col = self._data_col
            ext_col = self._ext_col
            ids = self.ids
            subjects = self.subjects
            types = self.types
            sources = self.sources
            specs = self.specversions
            new = object.__new__
            cls = _CloudEvent
            out = []
            for i in range(len(ids)):
                ev = new(cls)
                ev.__dict__.update({
                    "subject": subjects[i],
                    "type": types[i],
                    "data": ({"result": data_col[i]} if tag == _D_RESULT
                             else data_col[i]),
                    "source": sources[i],
                    "id": ids[i],
                    "time": self.time_at(i),
                    "specversion": specs[i],
                    "ext": None if ext_col is None else ext_col[i],
                })
                out.append(ev)
            self._events = out
        return self._events

    def __getitem__(self, i):
        return self.events()[i]

    def __iter__(self):
        return iter(self.events())


# ---------------------------------------------------------------------------
# payload-level helpers shared by the stores

def decode_payload(payload):
    """Decode one record payload: a columnar frame (NUL-tagged bytes)
    becomes an :class:`EventColumns`; anything else is JSON (bytes or
    str) and decodes to the raw JSON value."""
    if isinstance(payload, (bytes, bytearray)) and payload[:1] == b"\x00":
        return decode_frame_payload(bytes(payload))
    return json.loads(payload)


def events_of(obj) -> list:
    """Normalize a decoded payload to a list of CloudEvents: a columnar
    frame materializes, a JSON array maps per element, a single JSON
    object becomes a one-event list."""
    if isinstance(obj, EventColumns):
        return obj.events()
    if isinstance(obj, list):
        return [event_from_dict(d) for d in obj]
    return [event_from_dict(obj)]
