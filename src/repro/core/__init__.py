# The paper's primary contribution: the Rich Trigger (ECA) service.
from .actions import (
    ACTIONS,
    BATCHED_ACTIONS,
    PYFUNCS,
    action,
    batched_action,
    pyfunc,
    register_action,
    register_pyfunc,
    run_action_batch,
)
from .autoscaler import KedaAutoscaler
from .conditions import (
    BATCHED_CONDITIONS,
    CONDITIONS,
    FIRE_RUN_CONDITIONS,
    batched_condition,
    condition,
    fire_run_condition,
    register_condition,
    scalar_sweep,
)
from .context import TriggerContext
from .events import (
    TYPE_FAILURE,
    TYPE_INIT,
    TYPE_TERMINATION,
    TYPE_TIMEOUT,
    TYPE_WORKFLOW_END,
    CloudEvent,
    failure_event,
    termination_event,
)
from .eventstore import EventStore, FileEventStore, MemoryEventStore
from .functions import FunctionBackend, TimerSource
from .service import Triggerflow
from .statestore import FileStateStore, MemoryStateStore, StateStore
from .triggers import Trigger, make_trigger, new_trigger_id
from .worker import TFWorker

__all__ = [
    "ACTIONS", "BATCHED_ACTIONS", "BATCHED_CONDITIONS", "CONDITIONS",
    "FIRE_RUN_CONDITIONS", "PYFUNCS", "CloudEvent",
    "EventStore",
    "FileEventStore", "FileStateStore", "FunctionBackend", "KedaAutoscaler",
    "MemoryEventStore", "MemoryStateStore", "StateStore", "TFWorker",
    "TimerSource", "Trigger", "TriggerContext", "Triggerflow", "TYPE_FAILURE",
    "TYPE_INIT", "TYPE_TERMINATION", "TYPE_TIMEOUT", "TYPE_WORKFLOW_END",
    "action", "batched_action", "batched_condition", "condition",
    "failure_event", "fire_run_condition",
    "make_trigger", "new_trigger_id", "pyfunc", "register_action",
    "register_condition", "register_pyfunc", "run_action_batch",
    "scalar_sweep", "termination_event",
]
