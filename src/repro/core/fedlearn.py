"""§5.4 — Federated Learning orchestrator built from two persistent triggers.

* ``round`` trigger — starts a training round: resets the aggregator, invokes
  every available client "function", arms a timeout, and decides at round end
  whether to continue or finish.
* ``aggregator`` trigger — a custom *threshold* condition: fires when
  ``threshold``·|clients| round-tagged termination events arrived, or when the
  round timeout event lands (so failed/straggler clients can never hang the
  workflow — Fig. 17 round 3).  Its action aggregates the partial weights from
  the object store, deletes intermediates, and signals the round trigger.

Clients are heterogeneous/unreliable by design: they receive
``{"round", "client", "model"}``, train locally, ``put`` their delta into the
object store and return its key.  The controller can be fully deprovisioned
during training: all orchestration state lives in trigger contexts.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

from .actions import register_pyfunc
from .conditions import register_condition
from .events import TYPE_TIMEOUT, termination_event
from .service import Triggerflow
from .triggers import make_trigger

_FL: Dict[str, "FederatedLearningOrchestrator"] = {}


class ObjectStore:
    """COS/S3 stand-in for model weights (events never carry big payloads —
    the paper's control/data-plane split, §3.3)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._data: Dict[str, Any] = {}
        self.puts = 0
        self.gets = 0

    def put(self, key: str, value: Any) -> str:
        with self._lock:
            self._data[key] = value
            self.puts += 1
        return key

    def get(self, key: str) -> Any:
        with self._lock:
            self.gets += 1
            return self._data[key]

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._data.keys())


def _fl_aggregator_condition(ctx, event, params) -> bool:
    """Round-scoped threshold join: stale events from earlier rounds are
    ignored; timeouts fire the aggregation with whatever arrived."""
    rnd = ctx.get("round", 0)
    data = event.data if isinstance(event.data, dict) else {}
    ev_round = data.get("round", (data.get("result") or {}).get("round")
               if isinstance(data.get("result"), dict) else None)
    if event.type == TYPE_TIMEOUT:
        if data.get("round") != rnd or ctx.get("done_round") == rnd:
            return False  # stale timer
        ctx["timed_out_rounds"] = ctx.get("timed_out_rounds", []) + [rnd]
        fire = ctx.get("count", 0) >= int(params.get("min_results", 1))
        if fire:
            ctx["done_round"] = rnd
            ctx["fired_results"] = ctx.get("results") or []
        return fire
    if ev_round != rnd or ctx.get("done_round") == rnd:
        return False
    cnt = ctx.get("count", 0) + 1
    ctx["count"] = cnt
    results = ctx.get("results") or []
    res = data.get("result")
    if isinstance(res, dict) and "round" in res and "result" in res:
        res = res["result"]  # unwrap round-tagged client payloads
    results.append(res)
    ctx["results"] = results
    expected = int(ctx.get("expected", 1))
    threshold = float(ctx.get("threshold", 1.0))
    import math

    if cnt >= max(1, math.ceil(expected * threshold)):
        ctx["done_round"] = rnd
        ctx["fired_results"] = results
        return True
    return False


register_condition("fl_aggregator", _fl_aggregator_condition)


class FederatedLearningOrchestrator:
    def __init__(
        self,
        tf: Triggerflow,
        workflow: str,
        client_fn: Callable[[Dict[str, Any]], Any],
        aggregate_fn: Callable[[List[Any], "ObjectStore"], Any],
        n_clients: int,
        rounds: int,
        threshold: float = 1.0,
        round_timeout: Optional[float] = None,
        object_store: Optional[ObjectStore] = None,
        stop_fn: Optional[Callable[[Any, int], bool]] = None,
    ) -> None:
        self.tf = tf
        self.workflow = workflow
        self.client_fn = client_fn
        self.aggregate_fn = aggregate_fn
        self.n_clients = n_clients
        self.rounds = rounds
        self.threshold = threshold
        self.round_timeout = round_timeout
        self.store = object_store or ObjectStore()
        self.stop_fn = stop_fn
        self.round_log: List[Dict[str, Any]] = []
        _FL[workflow] = self

    def deploy(self) -> None:
        self.tf.create_workflow(self.workflow, {"kind": "fedlearn"})
        self.tf.backend.register(f"{self.workflow}:client", self.client_fn)
        round_trg = make_trigger(
            "fl|round",
            action={"name": "pyfunc", "func": "fl.round", "fl": self.workflow},
            trigger_id=f"{self.workflow}/round",
            transient=False,
        )
        agg_trg = make_trigger(
            "fl|agg",
            condition={"name": "fl_aggregator", "min_results": 1},
            action={"name": "pyfunc", "func": "fl.aggregate", "fl": self.workflow},
            trigger_id=f"{self.workflow}/agg",
            transient=False,
            context={"round": -1},
        )
        self.tf.add_trigger(self.workflow, [round_trg, agg_trg])

    def start(self, init_model: Any, timeout: float = 120.0) -> Any:
        self.store.put("model/0", init_model)
        self.tf.publish(self.workflow,
                        termination_event("fl|round", result={"round": 0, "model": "model/0"}))
        return self.tf.run_until_complete(self.workflow, timeout=timeout)


def _fl_round(ctx, event, params) -> None:
    fl = _FL[params["fl"]]
    data = (event.data or {}).get("result") or {}
    rnd, model_key = int(data.get("round", 0)), data.get("model")
    stop = rnd >= fl.rounds or (fl.stop_fn is not None
                                and fl.stop_fn(fl.store.get(model_key), rnd))
    if stop:
        ctx.workflow_result({"status": "succeeded",
                             "result": {"model": model_key, "rounds": rnd}})
        return
    # arm the aggregator for this round via introspection (§3.2 Context)
    agg_ctx = ctx.get_trigger_context(f"{fl.workflow}/agg")
    agg_ctx.update({"round": rnd, "expected": fl.n_clients, "count": 0,
                    "results": [], "threshold": fl.threshold, "model": model_key})
    for i in range(fl.n_clients):
        ctx.invoke(f"{fl.workflow}:client",
                   {"round": rnd, "client": i, "model": model_key}, "fl|agg")
    if fl.round_timeout is not None:
        ctx.timeout("fl|agg", fl.round_timeout, data={"round": rnd})


def _fl_aggregate(ctx, event, params) -> None:
    fl = _FL[params["fl"]]
    rnd = ctx.get("round", 0)
    results = [r for r in (ctx.get("fired_results") or []) if r is not None]
    new_model = fl.aggregate_fn(results, fl.store)
    new_key = fl.store.put(f"model/{rnd + 1}", new_model)
    for r in results:  # delete intermediate client deltas (paper §5.4)
        if isinstance(r, str):
            fl.store.delete(r)
    fl.round_log.append({"round": rnd, "n_results": len(results),
                         "timed_out": rnd in (ctx.get("timed_out_rounds") or [])})
    ctx.produce(termination_event(
        "fl|round", result={"round": rnd + 1, "model": new_key}))


register_pyfunc("fl.round", _fl_round)
register_pyfunc("fl.aggregate", _fl_aggregate)
