"""Action registry (paper §3.2: Actions are user-defined computations fired
when a Condition matches).

An action is ``fn(context, event, params) -> None``.  Like conditions, actions
are referenced by registry name + JSON params.  The generic ``pyfunc`` action
dispatches to runtime-registered callables — that is the extension point the
DAG / state-machine / workflow-as-code orchestrators build on.

Batched-action protocol (the worker's action plane)
---------------------------------------------------
An action may additionally register a *batched* implementation
``fn_batch(ctx, events, params) -> None`` via
``register_action(name, fn, batched=fn_batch)``.  The contract:

* ``events`` is the non-empty run of events that *fired* one trigger within
  one ``(subject, type)`` slice, in arrival order.
* The batched fn must be observably identical to folding the scalar fn over
  the run (``for e in events: fn(ctx, e, params)``); it exists purely to
  amortize the per-fire interpreter dispatch (one registry lookup, one
  context access pattern, one bulk ``produce``/publish instead of N).
* Batched implementations must not assume per-fire interleaving with the
  condition: when the worker takes the action plane, *all* condition
  evaluations of the run happen before the batched action runs.  Actions
  whose scalar form depends on that interleaving (``invoke`` result chains
  through external state, ``intercepted`` cancel flags, ``pyfunc`` user
  code) simply do not register a batched form and keep the exact scalar
  path — the worker falls back automatically.
* A batched fn should be *slice-isolating*: an error for one event must not
  silently swallow the rest of the run (prefer per-event try/except or
  building the whole output before any side effect).
* A batched fn must not disable its own trigger mid-run: by the time the
  worker can observe the disable, every fire's action has already run,
  whereas the per-fire oracle stops at the disabling fire.  An action that
  needs self-disable (or any per-fire trigger-state choreography) simply
  must not register a batched form — the worker then keeps the exact
  per-fire path, which re-checks ``enabled`` between fires.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from .events import CloudEvent, termination_event

ActionFn = Callable[[Any, CloudEvent, Dict[str, Any]], None]
BatchedActionFn = Callable[[Any, List[CloudEvent], Dict[str, Any]], None]

ACTIONS: Dict[str, ActionFn] = {}
#: Opt-in batched implementations, keyed like ``ACTIONS``.
BATCHED_ACTIONS: Dict[str, BatchedActionFn] = {}
# Runtime-registered python callables used by the ``pyfunc`` action.
PYFUNCS: Dict[str, Callable] = {}


def action(name: str, batched: Optional[BatchedActionFn] = None
           ) -> Callable[[ActionFn], ActionFn]:
    def deco(fn: ActionFn) -> ActionFn:
        register_action(name, fn, batched=batched)
        return fn

    return deco


def register_action(name: str, fn: ActionFn,
                    batched: Optional[BatchedActionFn] = None) -> None:
    """Third-party extension point.  ``batched`` opts the action into the
    worker's action plane; without it every fire runs the scalar fn."""
    ACTIONS[name] = fn
    if batched is not None:
        BATCHED_ACTIONS[name] = batched
    else:
        # re-registering without a batched impl must not leave a stale one
        BATCHED_ACTIONS.pop(name, None)


def batched_action(name: str) -> Callable[[BatchedActionFn], BatchedActionFn]:
    """Attach a batched implementation to an already-registered action."""
    def deco(fn: BatchedActionFn) -> BatchedActionFn:
        BATCHED_ACTIONS[name] = fn
        return fn

    return deco


def register_pyfunc(name: str, fn: Callable) -> None:
    PYFUNCS[name] = fn


def pyfunc(name: str) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        PYFUNCS[name] = fn
        return fn

    return deco


@action("noop")
def _noop(ctx, event, params) -> None:
    return None


@batched_action("noop")
def _noop_batch(ctx, events, params) -> None:
    return None


@action("invoke")
def _invoke(ctx, event, params) -> None:
    """Asynchronously invoke a backend 'serverless function'.

    Input chaining (§5.2): if ``pass_result`` is set, the previous state's
    output (the activating event's result) becomes this function's input.
    """
    args = params.get("args")
    if params.get("pass_result") and isinstance(event.data, dict):
        args = event.data.get("result")
    ctx.invoke(params["fn"], args, params["subject"], delay=params.get("delay", 0.0))


@action("map_invoke")
def _map_invoke(ctx, event, params) -> None:
    """Fan out N invocations and *introspect* the downstream join trigger to
    set its expected aggregation count (§5.1: dynamic condition update —
    the map width may be unknown until execution)."""
    items = params.get("items")
    if items is None and isinstance(event.data, dict):
        items = event.data.get("result")
    items = list(items if items is not None else [])
    join_trigger = params.get("join_trigger")
    if join_trigger:
        ctx.get_trigger_context(join_trigger)["expected"] = len(items)
    for it in items:
        ctx.invoke(params["fn"], it, params["subject"], delay=params.get("delay", 0.0))


@action("produce")
def _produce(ctx, event, params) -> None:
    """Produce a termination event into the worker's internal sink (§5.2)."""
    result = params.get("result")
    if params.get("pass_result") and isinstance(event.data, dict):
        result = event.data.get("result")
    ctx.produce(termination_event(params["subject"], result=result))


@batched_action("produce")
def _produce_batch(ctx, events, params) -> None:
    """Build the whole run's termination events, then sink them in one bulk
    publish (one append per partition / one commit-log write, not one per
    event).  Building first keeps the run slice-isolating: a bad event fails
    before any side effect lands."""
    subject = params["subject"]
    default = params.get("result")
    if params.get("pass_result"):
        out = [termination_event(
            subject,
            e.data.get("result") if isinstance(e.data, dict) else default)
            for e in events]
    else:
        out = [termination_event(subject, default) for _ in events]
    ctx.produce_batch(out)


@action("workflow_end")
def _workflow_end(ctx, event, params) -> None:
    result = params.get("result")
    if params.get("pass_result") and isinstance(event.data, dict):
        result = event.data.get("result")
    status = params.get("status", "succeeded")
    ctx.workflow_result({"status": status, "result": result})


@batched_action("workflow_end")
def _workflow_end_batch(ctx, events, params) -> None:
    # Exact scalar fold: ``set_result`` runs per fire (last one wins), so a
    # re-fired end trigger observes identical store-write semantics.
    for e in events:
        _workflow_end(ctx, e, params)


@action("chain")
def _chain(ctx, event, params) -> None:
    for spec in params.get("actions", []):
        run_action(spec, ctx, event)


@batched_action("chain")
def _chain_batch(ctx, events, params) -> None:
    """A single-action chain batches its sub-action directly.  Multi-action
    chains keep the scalar per-event interleaving (a1(e1) a2(e1) a1(e2) …):
    reordering to a1(e1) a1(e2) a2(e1) … could flip same-subject sink order,
    which the ordering contract does guarantee."""
    specs = params.get("actions", [])
    if len(specs) == 1:
        run_action_batch(specs[0], ctx, events)
        return
    for e in events:
        for spec in specs:
            run_action(spec, ctx, e)


@action("intercepted")
def _intercepted(ctx, event, params) -> None:
    """Dynamic trigger interception (Def. 5): run the interceptor, then the
    original action unless the interceptor cancelled it via context."""
    run_action(params["interceptor"], ctx, event)
    if not ctx.get("cancel_inner", False):
        run_action(params["inner"], ctx, event)


@action("pyfunc")
def _pyfunc(ctx, event, params) -> None:
    PYFUNCS[params["func"]](ctx, event, params)


def run_action(spec: Dict[str, Any], ctx, event: CloudEvent) -> None:
    ACTIONS[spec["name"]](ctx, event, spec)


def run_action_batch(spec: Dict[str, Any], ctx, events: List[CloudEvent]) -> None:
    """Run a fire run through the batched impl, or fold the scalar fn."""
    bafn = BATCHED_ACTIONS.get(spec["name"])
    if bafn is not None:
        bafn(ctx, events, spec)
        return
    fn = ACTIONS[spec["name"]]
    for e in events:
        fn(ctx, e, spec)


def batchable_action(spec: Dict[str, Any]) -> bool:
    """True when the whole action tree has batched implementations — the
    worker's gate for the action plane.  A ``chain`` is only batchable when
    every sub-action is: a chain-wrapped scalar-only action (``pyfunc``,
    ``invoke``, ``intercepted``) must keep the exact per-fire path, where
    the worker re-checks trigger state between fires."""
    if spec["name"] not in BATCHED_ACTIONS:
        return False
    if spec["name"] == "chain":
        return all(batchable_action(s) for s in spec.get("actions", []))
    return True


def run_condition(spec: Dict[str, Any], ctx, event: CloudEvent) -> bool:
    return _CONDITIONS()[spec["name"]](ctx, event, spec)


_conditions_registry = None


def _CONDITIONS():
    # conditions.py imports nothing from here, but resolve lazily-once anyway
    # to keep import order flexible; the per-call import this replaces showed
    # up as ~5% of the worker hot loop.
    global _conditions_registry
    if _conditions_registry is None:
        from .conditions import CONDITIONS as reg

        _conditions_registry = reg
    return _conditions_registry
