"""Action registry (paper §3.2: Actions are user-defined computations fired
when a Condition matches).

An action is ``fn(context, event, params) -> None``.  Like conditions, actions
are referenced by registry name + JSON params.  The generic ``pyfunc`` action
dispatches to runtime-registered callables — that is the extension point the
DAG / state-machine / workflow-as-code orchestrators build on.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

from .events import CloudEvent, termination_event

ActionFn = Callable[[Any, CloudEvent, Dict[str, Any]], None]

ACTIONS: Dict[str, ActionFn] = {}
# Runtime-registered python callables used by the ``pyfunc`` action.
PYFUNCS: Dict[str, Callable] = {}


def action(name: str) -> Callable[[ActionFn], ActionFn]:
    def deco(fn: ActionFn) -> ActionFn:
        ACTIONS[name] = fn
        return fn

    return deco


def register_action(name: str, fn: ActionFn) -> None:
    ACTIONS[name] = fn


def register_pyfunc(name: str, fn: Callable) -> None:
    PYFUNCS[name] = fn


def pyfunc(name: str) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        PYFUNCS[name] = fn
        return fn

    return deco


@action("noop")
def _noop(ctx, event, params) -> None:
    return None


@action("invoke")
def _invoke(ctx, event, params) -> None:
    """Asynchronously invoke a backend 'serverless function'.

    Input chaining (§5.2): if ``pass_result`` is set, the previous state's
    output (the activating event's result) becomes this function's input.
    """
    args = params.get("args")
    if params.get("pass_result") and isinstance(event.data, dict):
        args = event.data.get("result")
    ctx.invoke(params["fn"], args, params["subject"], delay=params.get("delay", 0.0))


@action("map_invoke")
def _map_invoke(ctx, event, params) -> None:
    """Fan out N invocations and *introspect* the downstream join trigger to
    set its expected aggregation count (§5.1: dynamic condition update —
    the map width may be unknown until execution)."""
    items = params.get("items")
    if items is None and isinstance(event.data, dict):
        items = event.data.get("result")
    items = list(items if items is not None else [])
    join_trigger = params.get("join_trigger")
    if join_trigger:
        ctx.get_trigger_context(join_trigger)["expected"] = len(items)
    for it in items:
        ctx.invoke(params["fn"], it, params["subject"], delay=params.get("delay", 0.0))


@action("produce")
def _produce(ctx, event, params) -> None:
    """Produce a termination event into the worker's internal sink (§5.2)."""
    result = params.get("result")
    if params.get("pass_result") and isinstance(event.data, dict):
        result = event.data.get("result")
    ctx.produce(termination_event(params["subject"], result=result))


@action("workflow_end")
def _workflow_end(ctx, event, params) -> None:
    result = params.get("result")
    if params.get("pass_result") and isinstance(event.data, dict):
        result = event.data.get("result")
    status = params.get("status", "succeeded")
    ctx.workflow_result({"status": status, "result": result})


@action("chain")
def _chain(ctx, event, params) -> None:
    for spec in params.get("actions", []):
        run_action(spec, ctx, event)


@action("intercepted")
def _intercepted(ctx, event, params) -> None:
    """Dynamic trigger interception (Def. 5): run the interceptor, then the
    original action unless the interceptor cancelled it via context."""
    run_action(params["interceptor"], ctx, event)
    if not ctx.get("cancel_inner", False):
        run_action(params["inner"], ctx, event)


@action("pyfunc")
def _pyfunc(ctx, event, params) -> None:
    PYFUNCS[params["func"]](ctx, event, params)


def run_action(spec: Dict[str, Any], ctx, event: CloudEvent) -> None:
    ACTIONS[spec["name"]](ctx, event, spec)


def run_condition(spec: Dict[str, Any], ctx, event: CloudEvent) -> bool:
    return _CONDITIONS()[spec["name"]](ctx, event, spec)


_conditions_registry = None


def _CONDITIONS():
    # conditions.py imports nothing from here, but resolve lazily-once anyway
    # to keep import order flexible; the per-call import this replaces showed
    # up as ~5% of the worker hot loop.
    global _conditions_registry
    if _conditions_registry is None:
        from .conditions import CONDITIONS as reg

        _conditions_registry = reg
    return _conditions_registry
