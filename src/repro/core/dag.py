"""§5.1 — Directed Acyclic Graph orchestration on top of triggers.

Airflow-style *Operator* abstraction.  Deployment registers one trigger per
vertex, activated by the termination events of its *upstream relatives*, with
a counter condition joining them.  Map operators dynamically set the expected
join count on their downstream triggers via context introspection.  Failure
events route to per-task error triggers which halt the workflow (and can
resume it by re-producing the missed event, §5.1 error handling).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from .actions import register_pyfunc
from .events import TYPE_FAILURE
from .service import Triggerflow
from .triggers import Trigger, make_trigger


class Operator:
    """Base operator: a named task with dependencies."""

    kind = "call_async"

    def __init__(self, task_id: str, fn: Optional[Callable] = None, args: Any = None,
                 retries: int = 0):
        self.task_id = task_id
        self.fn = fn
        self.args = args
        self.retries = retries
        self.upstream: List["Operator"] = []
        self.downstream: List["Operator"] = []

    def __rshift__(self, other):  # a >> b
        if isinstance(other, (list, tuple)):
            for o in other:
                self.__rshift__(o)
            return other
        self.downstream.append(other)
        other.upstream.append(self)
        return other

    def __lshift__(self, other):  # a << b
        if isinstance(other, (list, tuple)):
            for o in other:
                self.__lshift__(o)
            return other
        other.__rshift__(self)
        return other

    # subjects
    @property
    def done(self) -> str:
        return f"{self.task_id}.done"


class PythonOperator(Operator):
    kind = "call_async"


class MapOperator(Operator):
    """Fan out ``fn`` over an iterable (static ``items`` or the upstream
    result).  Downstream joins aggregate len(items) events."""

    kind = "map"

    def __init__(self, task_id: str, fn: Callable, items: Any = None, **kw):
        super().__init__(task_id, fn, **kw)
        self.items = items


class DAG:
    def __init__(self, dag_id: str):
        self.dag_id = dag_id
        self.tasks: Dict[str, Operator] = {}

    def add(self, op: Operator) -> Operator:
        if op.task_id in self.tasks:
            raise ValueError(f"duplicate task {op.task_id}")
        self.tasks[op.task_id] = op
        return op

    def roots(self) -> List[Operator]:
        return [t for t in self.tasks.values() if not t.upstream]

    def leaves(self) -> List[Operator]:
        return [t for t in self.tasks.values() if not t.downstream]

    def validate(self) -> None:
        """Reject cycles (a DAG must be acyclic)."""
        state: Dict[str, int] = {}

        def visit(op: Operator) -> None:
            if state.get(op.task_id) == 1:
                raise ValueError(f"cycle through {op.task_id}")
            if state.get(op.task_id) == 2:
                return
            state[op.task_id] = 1
            for d in op.downstream:
                visit(d)
            state[op.task_id] = 2

        for r in self.roots():
            visit(r)
        if len(state) != len(self.tasks):
            raise ValueError("disconnected tasks never reachable from a root")

    # -- compile the DAG to a trigger set Δ (paper Def. 3) ----------------------
    def deploy(self, tf: Triggerflow, workflow: str, on_failure: str = "halt") -> None:
        self.validate()
        tf.create_workflow(workflow, {"kind": "dag", "dag_id": self.dag_id})
        triggers: List[Trigger] = []
        for op in self.tasks.values():
            tf.backend.register(f"{workflow}:{op.task_id}", op.fn or (lambda x: x))
            subjects = [u.done for u in op.upstream] or ["$init"]
            n_map = sum(1 for u in op.upstream if isinstance(u, MapOperator))
            n_static = len(op.upstream) - n_map
            # join-count is dynamic when any upstream is a Map: the map action
            # sets ctx['expected'] via introspection before fanning out (§5.1).
            expected = max(1, len(op.upstream)) if n_map == 0 else 10 ** 9
            action = self._action_for(tf, workflow, op)
            trg = make_trigger(
                subjects,
                condition={"name": "counter", "expected": expected, "aggregate": True},
                action=action,
                trigger_id=f"{workflow}/{op.task_id}",
                context={"retries_left": op.retries, "expected_static": n_static},
            )
            triggers.append(trg)
            # failure handling trigger (halts; resumable by re-producing event)
            trg_fail = make_trigger(
                [op.done],
                condition={"name": "event_type", "type": TYPE_FAILURE},
                action={"name": "pyfunc", "func": "dag.on_failure", "workflow": workflow,
                        "task": op.task_id, "policy": on_failure,
                        "fn": f"{workflow}:{op.task_id}"},
                trigger_id=f"{workflow}/{op.task_id}/onfail",
                context={"retries_left": op.retries},
                transient=False,
                event_type=TYPE_FAILURE,
            )
            triggers.append(trg_fail)
        # workflow completion: join of all leaf tasks
        leaves = self.leaves()
        n_map_leaves = sum(1 for l in leaves if isinstance(l, MapOperator))
        triggers.append(
            make_trigger(
                [l.done for l in leaves],
                condition={"name": "counter",
                           "expected": len(leaves) if n_map_leaves == 0 else 10 ** 9},
                action={"name": "workflow_end", "pass_result": True},
                trigger_id=f"{workflow}/$end",
                context={"expected_static": len(leaves) - n_map_leaves},
            )
        )
        # Map leaves: their fan-out sets $end's expected dynamically.
        tf.add_trigger(workflow, triggers)

    def _action_for(self, tf: Triggerflow, workflow: str, op: Operator) -> Dict[str, Any]:
        downstream_joins = [f"{workflow}/{d.task_id}" for d in op.downstream]
        if not op.downstream:
            downstream_joins = [f"{workflow}/$end"]
        if isinstance(op, MapOperator):
            return {
                "name": "pyfunc", "func": "dag.map_exec",
                "fn": f"{workflow}:{op.task_id}",
                "items": op.items, "subject": op.done,
                "join_triggers": downstream_joins,
            }
        return {
            "name": "pyfunc", "func": "dag.call_async",
            "fn": f"{workflow}:{op.task_id}", "args": op.args,
            "subject": op.done, "n_upstream": len(op.upstream),
            "map_upstream": any(isinstance(u, MapOperator) for u in op.upstream),
        }

    def run(self, tf: Triggerflow, workflow: str, timeout: float = 60.0,
            data: Any = None) -> Any:
        tf.init_workflow(workflow, data=data)
        return tf.run_until_complete(workflow, timeout=timeout)


# -- pyfunc implementations ------------------------------------------------------
def _dag_call_async(ctx, event, params) -> None:
    args = params.get("args")
    if args is None:
        results = ctx.get("results") or []
        if params.get("n_upstream", 0) <= 1 and not params.get("map_upstream"):
            args = results[-1] if results else (
                event.data.get("result") if isinstance(event.data, dict) else event.data)
        else:
            args = list(results)  # joined upstreams (incl. map fan-in) pass all
    ctx.invoke(params["fn"], args, params["subject"])


def _dag_map_exec(ctx, event, params) -> None:
    items = params.get("items")
    if items is None:
        results = ctx.get("results") or []
        items = results[-1] if results else None
    items = list(items if items is not None else [])
    for join_id in params.get("join_triggers", []):
        jctx = ctx.get_trigger_context(join_id)
        # Accumulate: static upstream count + every map's dynamic width.
        base = jctx.get("expected", jctx.get("expected_static", 0))
        base = base if base < 10 ** 9 else jctx.get("expected_static", 0)
        jctx["expected"] = base + len(items)
    for it in items:
        ctx.invoke(params["fn"], it, params["subject"])


def _dag_on_failure(ctx, event, params) -> None:
    err = (event.data or {}).get("error") if isinstance(event.data, dict) else str(event.data)
    retries = ctx.get("retries_left", 0)
    if retries > 0:
        ctx["retries_left"] = retries - 1
        ctx.invoke(params["fn"], None, event.subject)
        return
    if params.get("policy") == "halt":
        ctx["halted_error"] = err
        ctx.workflow_result({"status": "failed", "error": err, "task": params.get("task")})


register_pyfunc("dag.call_async", _dag_call_async)
register_pyfunc("dag.map_exec", _dag_map_exec)
register_pyfunc("dag.on_failure", _dag_on_failure)
