"""KEDA-style event-driven autoscaler (paper §4.2, Fig. 8).

Control loop: poll per-workflow queue *lag* (uncommitted events — exactly the
metric KEDA's Kafka scaler uses).

Classic mode (unpartitioned store): ``lag > 0`` and no live worker →
provision a TF-Worker (scale 0→1).  A worker idle longer than the grace
period exits and is reaped (scale →0).  Crashed workers are restarted
(deployment fault tolerance, §4.1/§4.2) and recover from the stores +
uncommitted events.  Departures are classified by the worker's *recorded*
exit reason (``TFWorker.crashed``): an idle exit is a ``scale_down``, a died
thread is a ``restart`` — never both.

Sharded mode: the autoscaler drives any pool implementing the
``ScalablePool`` protocol below — the threaded ``ShardedWorkerPool`` and the
multiprocess ``ProcessShardPool`` (one OS process per shard over the durable
file bus, the paper's Knative/KEDA container-per-worker deployment) are
interchangeable.  The target is *lag-proportional* —
``ceil(lag / events_per_shard)`` worker shards, capped by
``max_shards_per_workflow`` and the **workflow's own** partition count (a
shard without a partition has nothing to consume, and per-workflow partition
pins on the file bus make the store-global count the wrong cap).  Scale-up
starts new shards (the consumer group rebalances partitions onto them — a
two-phase ack'd handoff on the process pool) and counts the pool's *actual*
delta, not the request.  Scale-down is idle-driven: shards (threads or
processes) exit after the grace period and are reaped, so a drained workflow
decays back to zero shards; ``reap()``'s exit-reason accounting feeds
``scale_downs`` vs ``restarts``.

The autoscaler records a ``timeline`` of (t, active_workers, total_lag)
samples — the data behind the Fig. 8 reproduction (active_workers counts
*shards* in sharded mode).  On the file bus an idle tick costs O(1) stat
calls — the store's publish-notify-gated ``lag`` — not O(partitions) disk
scans.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional, Protocol, Tuple

from .policy import CircuitBreaker
from .service import Triggerflow


class ScalablePool(Protocol):
    """What a shard pool must expose for the autoscaler to drive it.

    Both ``repro.bus.ShardedWorkerPool`` (threads over the in-memory bus) and
    ``repro.bus.ProcessShardPool`` (OS processes over the durable file bus)
    implement this structurally — the autoscaler never needs to know which
    substrate runs the shards.
    """

    def live_shard_count(self, workflow: str) -> int:
        """Shards actually executing right now (0 after scale-to-zero)."""
        ...

    def start_shards(self, workflow: str, count: int,
                     idle_timeout: Optional[float] = None) -> List[str]:
        """Ensure ``count`` live shards; arms idle-exit with the grace
        period.  May start fewer than asked (partition caps, spawn
        failures) — callers must measure the actual delta."""
        ...

    def reap(self, workflow: str) -> Dict:
        """Retire departed shards.  Returns ``{"reaped": n, "crashed": m,
        "reasons": {...}}`` with crashes classified by recorded exit
        reason."""
        ...

    def lag(self, workflow: str) -> int:
        """Uncommitted events — the scaling metric.  Idle polls must be
        cheap (publish-notify-gated on the file bus)."""
        ...

    def num_partitions(self, workflow: str) -> int:
        """The *workflow's* partition count — the hard shard cap."""
        ...


class KedaAutoscaler:
    def __init__(
        self,
        tf: Triggerflow,
        poll_interval: float = 0.05,
        grace_period: float = 0.5,
        max_workers: int = 64,
        events_per_shard: int = 1000,
        max_shards_per_workflow: int = 8,
        breaker: Optional[Dict] = None,
    ) -> None:
        self.tf = tf
        self.poll_interval = poll_interval
        self.grace_period = grace_period
        self.max_workers = max_workers
        self.events_per_shard = max(1, events_per_shard)
        self.max_shards_per_workflow = max(1, max_shards_per_workflow)
        self.timeline: List[Tuple[float, int, int]] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.restarts = 0
        # host-loss recoveries observed via reap(): the recovery's restart
        # storm is deliberate, so it is neither a scale-down nor a crash
        self.node_recoveries = 0
        self._live: Dict[str, threading.Thread] = {}
        # Classic-mode crash-loop breakers, one per workflow: a worker whose
        # loop keeps dying gets restarted with exponential backoff and is
        # circuit-broken past the threshold (sharded mode delegates to the
        # pool's own per-workflow breaker inside start_shards).
        self.breaker_conf = dict(breaker) if breaker else {}
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._stop = threading.Event()
        # serializes ticks; stop() drains the in-flight one through it, so a
        # tick caught mid-start_shards can never outlive the autoscaler and
        # leave freshly started shards unreaped
        self._tick_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()

    # -- control loop -------------------------------------------------------------
    def _tick(self) -> None:
        with self._tick_lock:
            if self.tf.pool is not None:
                self._tick_sharded()
            else:
                self._tick_classic()

    def _tick_classic(self) -> None:
        lags = {wf: self.tf.event_store.lag(wf) for wf in self.tf.event_store.workflows()}
        # Reap exited workers: a clean departure (idle / stopped / finished)
        # is a scale-down, a died loop is a restart — separate counters, one
        # increment per exit, classified by the worker's public predicate.
        for wf, th in list(self._live.items()):
            if not th.is_alive():
                worker = self.tf._workers.get(wf)
                del self._live[wf]
                if worker is not None and worker.crashed:
                    self.restarts += 1
                    self._breaker(wf).record_crash()
                else:
                    self.scale_downs += 1
                    self._breaker(wf).record_clean()
        # Provision workers for workflows with lag.
        for wf, lag in lags.items():
            if lag <= 0 or wf in self._live or len(self._live) >= self.max_workers:
                continue
            if self._breaker(wf).allow_start(1) < 1:
                continue  # crash-looping workflow: backing off / circuit open
            worker = self.tf.worker(wf)
            if worker.finished:
                continue
            worker.last_active = time.monotonic()
            th = self.tf.start_worker(wf, idle_timeout=self.grace_period)
            self._live[wf] = th
            self.scale_ups += 1
        self.timeline.append(
            (time.monotonic() - self._t0, len(self._live), sum(lags.values()))
        )

    def _breaker(self, workflow: str) -> CircuitBreaker:
        br = self._breakers.get(workflow)
        if br is None:
            br = self._breakers[workflow] = CircuitBreaker(**self.breaker_conf)
        return br

    def breaker_of(self, workflow: str) -> CircuitBreaker:
        """The breaker gating restarts of ``workflow`` — the pool's own in
        sharded mode, the autoscaler's in classic mode."""
        pool = self.tf.pool
        if pool is not None and hasattr(pool, "breaker_of"):
            return pool.breaker_of(workflow)
        return self._breaker(workflow)

    def target_shards(self, lag: int, workflow: Optional[str] = None) -> int:
        """Lag-proportional shard target (0 when the stream is drained),
        capped by the *workflow's* partition count when one is named — on a
        bus with per-workflow partition pins the store-global count would
        over-cap narrow workflows and under-cap wide ones."""
        if lag <= 0:
            return 0
        if workflow is not None and self.tf.pool is not None:
            partitions = self.tf.pool.num_partitions(workflow)
        else:
            partitions = getattr(self.tf.event_store, "num_partitions",
                                 self.max_shards_per_workflow)
        return min(
            self.max_shards_per_workflow,
            partitions,
            math.ceil(lag / self.events_per_shard),
        )

    def _tick_sharded(self) -> None:
        pool: ScalablePool = self.tf.pool
        store = self.tf.event_store
        workflows = store.workflows()
        lags: Dict[str, int] = {}
        lives: Dict[str, int] = {}
        for wf in workflows:
            reaped = pool.reap(wf)
            host_lost = reaped["reasons"].get("host-loss", 0)
            self.scale_downs += reaped["reaped"] - reaped["crashed"] - host_lost
            self.restarts += reaped["crashed"]
            self.node_recoveries += reaped.get("node_recoveries", 0)
            lags[wf] = pool.lag(wf)
            lives[wf] = pool.live_shard_count(wf)
        # max_workers caps the *total* shard count across workflows, so the
        # budget must see every workflow's live shards, not just the ones
        # iterated so far.
        total_live = sum(lives.values())
        for wf in workflows:
            live = lives[wf]
            target = self.target_shards(lags[wf], wf)
            budget = self.max_workers - total_live
            if target > live and budget > 0:
                # the workflow-meta read costs a state-store round-trip, so
                # only pay it when this tick would actually scale up
                meta = self.tf.state_store.get_workflow(wf) or {}
                if meta.get("status") in ("succeeded", "failed"):
                    continue
                want = min(target, live + budget)
                pool.start_shards(wf, want, idle_timeout=self.grace_period)
                # count what the pool actually started — partition caps or
                # spawn failures may grant fewer shards than requested
                now_live = pool.live_shard_count(wf)
                self.scale_ups += max(0, now_live - live)
                lives[wf] = now_live
                total_live += now_live - live
        self.timeline.append(
            (time.monotonic() - self._t0, sum(lives.values()), sum(lags.values())))

    def run(self) -> None:
        while not self._stop.is_set():
            self._tick()
            self._stop.wait(self.poll_interval)

    def start(self) -> "KedaAutoscaler":
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self.run, name="keda-autoscaler", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 60.0) -> None:
        """Stop the control loop and *drain the in-flight tick* before
        returning.  A tick caught mid-``start_shards`` (process spawns can
        take seconds) must finish under the autoscaler's watch — returning
        early would leave its freshly started shards running unreaped after
        the caller believes autoscaling is over (the ``launch/serve.py``
        shutdown path: scaler.stop() then tf.shutdown())."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        with self._tick_lock:  # drain a tick the join timeout abandoned
            pass

    @property
    def active_workers(self) -> int:
        n = len([th for th in self._live.values() if th.is_alive()])
        if self.tf.pool is not None:
            for wf in self.tf.event_store.workflows():
                n += self.tf.pool.live_shard_count(wf)
        return n

    def metrics_snapshot(self) -> Dict:
        """The autoscaler's counters as a named-metric snapshot — the same
        shape the shard pools scrape, so ``merge_snapshot`` composes the
        Fig-8 control loop into one export (``launch/serve.py``)."""
        from ..obs.metrics import empty_snapshot, fold_counters
        snap = empty_snapshot()
        fold_counters(snap, {
            "tf_scale_ups_total": self.scale_ups,
            "tf_scale_downs_total": self.scale_downs,
            "tf_restarts_total": self.restarts,
            "tf_autoscaler_node_recoveries_total": self.node_recoveries,
            # classic-mode breakers only; sharded-mode breakers report
            # through their pool's obs_snapshot (no double counting)
            "tf_circuit_open_total":
                sum(b.opened_total for b in self._breakers.values()),
        })
        snap["gauges"]["tf_active_workers"] = self.active_workers
        snap["gauges"]["tf_restart_backoff_seconds"] = sum(
            b.restart_backoff() for b in self._breakers.values())
        return snap
