"""KEDA-style event-driven autoscaler (paper §4.2, Fig. 8).

Control loop: poll per-workflow queue *lag* (uncommitted events — exactly the
metric KEDA's Kafka scaler uses).  ``lag > 0`` and no live worker → provision
a TF-Worker (scale 0→1).  A worker that has been idle longer than the grace
period exits and is reaped (scale →0).  Crashed workers are restarted
(deployment fault tolerance, §4.1/§4.2) and recover their state from the
stores + uncommitted events.

The autoscaler records a ``timeline`` of (t, active_workers, total_lag)
samples — the data behind the Fig. 8 reproduction.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from .service import Triggerflow


class KedaAutoscaler:
    def __init__(
        self,
        tf: Triggerflow,
        poll_interval: float = 0.05,
        grace_period: float = 0.5,
        max_workers: int = 64,
    ) -> None:
        self.tf = tf
        self.poll_interval = poll_interval
        self.grace_period = grace_period
        self.max_workers = max_workers
        self.timeline: List[Tuple[float, int, int]] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.restarts = 0
        self._live: Dict[str, threading.Thread] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()

    # -- control loop -------------------------------------------------------------
    def _tick(self) -> None:
        lags = {wf: self.tf.event_store.lag(wf) for wf in self.tf.event_store.workflows()}
        # Reap exited workers (idle scale-down or crash).
        for wf, th in list(self._live.items()):
            if not th.is_alive():
                worker = self.tf._workers.get(wf)
                crashed = worker is not None and not worker.finished and not worker._stop.is_set() \
                    and lags.get(wf, 0) > 0 and time.monotonic() - worker.last_active < self.grace_period
                del self._live[wf]
                self.scale_downs += 1
                if crashed:
                    self.restarts += 1
        # Provision workers for workflows with lag.
        for wf, lag in lags.items():
            if lag <= 0 or wf in self._live or len(self._live) >= self.max_workers:
                continue
            worker = self.tf.worker(wf)
            if worker.finished:
                continue
            worker.last_active = time.monotonic()
            th = self.tf.start_worker(wf, idle_timeout=self.grace_period)
            self._live[wf] = th
            self.scale_ups += 1
        self.timeline.append(
            (time.monotonic() - self._t0, len(self._live), sum(lags.values()))
        )

    def run(self) -> None:
        while not self._stop.is_set():
            self._tick()
            time.sleep(self.poll_interval)

    def start(self) -> "KedaAutoscaler":
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self.run, name="keda-autoscaler", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    @property
    def active_workers(self) -> int:
        return len([th for th in self._live.values() if th.is_alive()])
