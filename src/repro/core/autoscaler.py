"""KEDA-style event-driven autoscaler (paper §4.2, Fig. 8).

Control loop: poll per-workflow queue *lag* (uncommitted events — exactly the
metric KEDA's Kafka scaler uses).

Classic mode (unpartitioned store): ``lag > 0`` and no live worker →
provision a TF-Worker (scale 0→1).  A worker idle longer than the grace
period exits and is reaped (scale →0).  Crashed workers are restarted
(deployment fault tolerance, §4.1/§4.2) and recover from the stores +
uncommitted events.

Sharded mode (``Triggerflow`` built over a ``repro.bus`` partitioned store):
the target is *lag-proportional* — ``ceil(lag / events_per_shard)`` worker
shards, capped by ``max_shards_per_workflow`` and the partition count (a
shard without a partition has nothing to consume).  Scale-up starts new
shards (the consumer group rebalances partitions onto them); scale-down is
still idle-driven: shards exit after the grace period and are reaped, so a
drained workflow decays back to zero shards.

The autoscaler records a ``timeline`` of (t, active_workers, total_lag)
samples — the data behind the Fig. 8 reproduction (active_workers counts
*shards* in sharded mode).
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional, Tuple

from .service import Triggerflow


class KedaAutoscaler:
    def __init__(
        self,
        tf: Triggerflow,
        poll_interval: float = 0.05,
        grace_period: float = 0.5,
        max_workers: int = 64,
        events_per_shard: int = 1000,
        max_shards_per_workflow: int = 8,
    ) -> None:
        self.tf = tf
        self.poll_interval = poll_interval
        self.grace_period = grace_period
        self.max_workers = max_workers
        self.events_per_shard = max(1, events_per_shard)
        self.max_shards_per_workflow = max(1, max_shards_per_workflow)
        self.timeline: List[Tuple[float, int, int]] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self.restarts = 0
        self._live: Dict[str, threading.Thread] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()

    # -- control loop -------------------------------------------------------------
    def _tick(self) -> None:
        if self.tf.pool is not None:
            self._tick_sharded()
            return
        lags = {wf: self.tf.event_store.lag(wf) for wf in self.tf.event_store.workflows()}
        # Reap exited workers (idle scale-down or crash).
        for wf, th in list(self._live.items()):
            if not th.is_alive():
                worker = self.tf._workers.get(wf)
                crashed = worker is not None and not worker.finished and not worker._stop.is_set() \
                    and lags.get(wf, 0) > 0 and time.monotonic() - worker.last_active < self.grace_period
                del self._live[wf]
                self.scale_downs += 1
                if crashed:
                    self.restarts += 1
        # Provision workers for workflows with lag.
        for wf, lag in lags.items():
            if lag <= 0 or wf in self._live or len(self._live) >= self.max_workers:
                continue
            worker = self.tf.worker(wf)
            if worker.finished:
                continue
            worker.last_active = time.monotonic()
            th = self.tf.start_worker(wf, idle_timeout=self.grace_period)
            self._live[wf] = th
            self.scale_ups += 1
        self.timeline.append(
            (time.monotonic() - self._t0, len(self._live), sum(lags.values()))
        )

    def target_shards(self, lag: int) -> int:
        """Lag-proportional shard target (0 when the stream is drained)."""
        if lag <= 0:
            return 0
        return min(
            self.max_shards_per_workflow,
            self.tf.event_store.num_partitions,
            math.ceil(lag / self.events_per_shard),
        )

    def _tick_sharded(self) -> None:
        pool = self.tf.pool
        store = self.tf.event_store
        workflows = store.workflows()
        lags: Dict[str, int] = {}
        lives: Dict[str, int] = {}
        for wf in workflows:
            reaped = pool.reap(wf)
            self.scale_downs += reaped["reaped"]
            self.restarts += reaped["crashed"]
            lags[wf] = store.lag(wf)
            lives[wf] = pool.live_shard_count(wf)
        # max_workers caps the *total* shard count across workflows, so the
        # budget must see every workflow's live shards, not just the ones
        # iterated so far.
        total_live = sum(lives.values())
        for wf in workflows:
            meta = self.tf.state_store.get_workflow(wf) or {}
            if meta.get("status") in ("succeeded", "failed"):
                continue
            live = lives[wf]
            target = self.target_shards(lags[wf])
            budget = self.max_workers - total_live
            if target > live and budget > 0:
                want = min(target, live + budget)
                pool.start_shards(wf, want, idle_timeout=self.grace_period)
                self.scale_ups += want - live
                lives[wf] = pool.live_shard_count(wf)
                total_live += lives[wf] - live
        self.timeline.append(
            (time.monotonic() - self._t0, sum(lives.values()), sum(lags.values())))

    def run(self) -> None:
        while not self._stop.is_set():
            self._tick()
            time.sleep(self.poll_interval)

    def start(self) -> "KedaAutoscaler":
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self.run, name="keda-autoscaler", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    @property
    def active_workers(self) -> int:
        n = len([th for th in self._live.values() if th.is_alive()])
        if self.tf.pool is not None:
            for wf in self.tf.event_store.workflows():
                n += self.tf.pool.live_shard_count(wf)
        return n
