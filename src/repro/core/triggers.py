"""Trigger = (Event, Context, Condition, Action) 4-tuple (paper Def. 2).

Triggers are *serializable*: conditions and actions are referenced by
registry name + JSON params, so a trigger survives a worker restart and can be
shipped to the state store — exactly what the paper needs for fault tolerance
and for dynamic trigger creation from inside actions (§5.3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

_tid = itertools.count()


def new_trigger_id(prefix: str = "tg") -> str:
    return f"{prefix}-{next(_tid):x}"


@dataclass
class Trigger:
    # Subjects of CloudEvents that activate this trigger.
    activation_events: List[str]
    condition: Dict[str, Any]  # {"name": <registry name>, ...params}
    action: Dict[str, Any]     # {"name": <registry name>, ...params}
    context: Dict[str, Any] = field(default_factory=dict)
    trigger_id: str = field(default_factory=new_trigger_id)
    transient: bool = True      # transient triggers deactivate after firing (Def. 2)
    enabled: bool = True
    # Optional filter on CloudEvent.type ("" = any).
    event_type: str = ""
    # Optional RetryPolicy spec (dict form — see core.policy).  None keeps the
    # pre-policy semantics: failures print and the event commits as consumed.
    retry_policy: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "trigger_id": self.trigger_id,
            "activation_events": list(self.activation_events),
            "condition": self.condition,
            "action": self.action,
            "context": self.context,
            "transient": self.transient,
            "enabled": self.enabled,
            "event_type": self.event_type,
        }
        if self.retry_policy is not None:
            d["retry_policy"] = self.retry_policy
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Trigger":
        return Trigger(
            activation_events=list(d["activation_events"]),
            condition=dict(d["condition"]),
            action=dict(d["action"]),
            context=dict(d.get("context", {})),
            trigger_id=d["trigger_id"],
            transient=d.get("transient", True),
            enabled=d.get("enabled", True),
            event_type=d.get("event_type", ""),
            retry_policy=d.get("retry_policy"),
        )


def make_trigger(
    subjects,
    condition: Optional[Dict[str, Any]] = None,
    action: Optional[Dict[str, Any]] = None,
    context: Optional[Dict[str, Any]] = None,
    trigger_id: Optional[str] = None,
    transient: bool = True,
    event_type: str = "",
    retry=None,
) -> Trigger:
    from .policy import coerce_retry_policy

    if isinstance(subjects, str):
        subjects = [subjects]
    return Trigger(
        activation_events=list(subjects),
        condition=condition or {"name": "true"},
        action=action or {"name": "noop"},
        context=context or {},
        trigger_id=trigger_id or new_trigger_id(),
        transient=transient,
        event_type=event_type,
        retry_policy=coerce_retry_policy(retry),
    )
