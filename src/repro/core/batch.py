"""Vector join plane: batched aggregation-condition evaluation as array ops.

The worker's batch plane evaluates conditions over ``(subject, type)``
slices.  This module is the fully-vectorized tier above that: a consumed
batch whose subjects route to aggregation joins (``counter`` — counting or
aggregating — and ``threshold_join``, without ``exactly_once`` dedup) that
provably cannot fire within the batch (``count + batch share < threshold``)
reduces to *counting plus column gathers* — no action runs, no per-event
interpreter dispatch, no per-event state changes except the counters and
the pre-extracted result columns.

``triage`` therefore never walks individual events through the condition
machinery: the batch is bucketed per subject C-level (one pass), each
distinct subject is screened against its compiled dispatch entries, all
claimed subjects are folded into one one-hot segmented sum over the routed
event batch — the ``event_join`` kernel (Pallas on TPU, jitted-jnp or
``bincount`` on CPU; see ``kernels.event_join.dispatch``) — and aggregating
triggers additionally get their ``data["result"]`` column appended in one
list-comprehension per (subject, trigger) run.  The Table-1 join hot loop
becomes O(batch) array/column ops plus O(distinct subjects) Python.

``triage`` also accepts an :class:`EventColumns` view straight off a
decoded TFB1 columnar frame (``core.codec``): ids/subjects/types and the
result column are then the decoded frame's own columns, so a fully-claimed
binary batch flows from the segment log into the ``event_join`` kernel
without ever materializing per-event CloudEvent objects.

Everything else — slices that would cross a threshold, dedup, timeouts,
failures, non-join conditions — is returned as leftover for the worker's
per-trigger fire-run/batched/scalar path, which owns the exact fire
semantics.  The screening is the correctness boundary: the kernel only ever
sees slices whose outcome is pure counting/aggregation, so parity with the
scalar interpreter is by construction.
"""
from __future__ import annotations

import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

try:  # numpy is the plane's only hard dependency; degrade to None without it
    import numpy as np
except ImportError:  # pragma: no cover - numpy is in the base image
    np = None

from .codec import EventColumns
from .conditions import _result_of
from .events import TYPE_FAILURE, TYPE_TIMEOUT, CloudEvent

TriageResult = Tuple[List[str], List[CloudEvent]]  # (handled ids, leftover)

#: Condition names ``triage`` can claim (absent ``exactly_once``).  The
#: worker's structural pre-screen (``TFWorker._has_join_triggers``) consumes
#: this, so extending claimability here automatically re-enables triage for
#: the new conditions.
CLAIMABLE_CONDITIONS = ("counter", "threshold_join")


class VectorJoinPlane:
    """Batch-level accelerator for non-firing aggregation-join batches."""

    def __init__(self, backend: Optional[str] = None, min_subjects: int = 2):
        if np is None:
            raise RuntimeError("VectorJoinPlane requires numpy")
        from ..kernels.event_join.dispatch import (join_counts_segments,
                                                   resolve_join_backend)

        self._join_segments = join_counts_segments
        self.backend, self._join = resolve_join_backend(backend)
        if self._join is None:
            raise RuntimeError("join backend disabled")
        # Below this many claimable subjects the per-trigger batched
        # conditions beat array assembly.
        self.min_subjects = min_subjects
        self.calls = 0
        self.events = 0

    @staticmethod
    def _screen_entry(entry, ctx) -> Optional[Tuple[int, bool]]:
        """(threshold, aggregates) for a claimable join condition, else None.

        Claimable: ``counter`` (either aggregation mode) or ``threshold_join``
        without ``exactly_once`` — their per-event effect on a non-firing,
        termination-typed slice is exactly "count += 1 (+ append result)".
        """
        cspec = entry.cspec
        if cspec.get("exactly_once"):
            return None
        expected = ctx.get("expected", cspec.get("expected", 1))
        if entry.cname == "counter":  # CLAIMABLE_CONDITIONS
            aggregates = bool(cspec.get("aggregate", True))
            threshold = int(expected)
        elif entry.cname == "threshold_join":  # CLAIMABLE_CONDITIONS
            frac = float(cspec.get("fraction", 1.0))
            aggregates = True
            threshold = max(1, math.ceil(int(expected) * frac))
        else:
            return None
        if aggregates:
            # a poisoned results value (introspection writing a non-list)
            # must be declined *here*: the apply loop below writes counts
            # before extending results, and an extend failure after that
            # would hand the batch to the exact path double-counted
            res = ctx.get("results")
            if res is not None and not isinstance(res, list):
                return None
        return threshold, aggregates

    def triage(self, batch: "List[CloudEvent] | EventColumns",
               entries_for: Callable[[str], Sequence[Any]],
               stats) -> Optional[TriageResult]:
        """Claim and evaluate the non-firing join share of a consumed batch.

        ``batch`` is either a list of CloudEvents (the in-memory bus) or an
        :class:`EventColumns` view straight off a decoded TFB1 frame — the
        columnar path never materializes per-event objects unless a split
        leaves events for the exact path.

        Returns ``(handled_event_ids, leftover_events)`` — the handled events
        have been fully accounted (counters advanced, result columns
        appended, activations counted) and only need committing; the
        leftovers carry every event the exact path must see.  Returns
        ``None`` when the batch isn't worth vectorizing (mixed types,
        failure/timeout slices, too few claimable subjects) — the caller
        then processes the whole batch normally.
        """
        cols = batch if isinstance(batch, EventColumns) else None
        if cols is not None:
            ids, subjects, types = cols.ids, cols.subjects, cols.types
        else:
            ids = [e.id for e in batch]
            subjects = [e.subject for e in batch]
            types = [e.type for e in batch]
        etype = types[0]
        if len(set(types)) != 1:
            return None
        if etype == TYPE_FAILURE or etype == TYPE_TIMEOUT:
            return None
        if len(set(ids)) != len(ids):
            # A re-published duplicate inside the batch: counting the copies
            # would double-count the join.  The grouped path's in-flight set
            # dedups exactly (§3.4), so leave the whole batch to it.
            return None
        # subject -> its arrival-ordered event indices (insertion order =
        # the order the grouped path would build its slices in)
        by_subject: dict = {}
        for i, s in enumerate(subjects):
            idxs = by_subject.get(s)
            if idxs is None:
                by_subject[s] = [i]
            else:
                idxs.append(i)
        # tid -> [ctx, count0, threshold, events_in_batch]
        pairs: dict = {}
        aggregating: dict = {}   # tid -> pre-extracted result column
        claimed: dict = {}       # subject -> its candidate tid list
        for subject, sidx in by_subject.items():
            m = len(sidx)
            entries = entries_for(subject)
            if not entries:
                continue  # unknown subject: worker's drop-count path
            cand = []
            for entry in entries:
                if not entry.matches(etype):
                    continue
                screened = self._screen_entry(entry, entry.ctx)
                if screened is None:
                    cand = None  # needs per-event work → exact path
                    break
                threshold, aggregates = screened
                ctx = entry.ctx
                tid = entry.trg.trigger_id
                prior = pairs.get(tid)
                count0 = prior[1] if prior is not None else ctx.get("count", 0)
                acc = prior[3] if prior is not None else 0
                if not isinstance(count0, int) or count0 + acc + m >= threshold:
                    cand = None  # could fire inside this batch
                    break
                cand.append((tid, ctx, count0, threshold, aggregates))
            if not cand:  # ineligible, or zero enabled candidates (DLQ path)
                continue
            for tid, ctx, count0, threshold, aggregates in cand:
                prior = pairs.get(tid)
                if prior is None:
                    pairs[tid] = [ctx, count0, threshold, m]
                    if aggregates:
                        aggregating[tid] = []
                else:
                    prior[3] += m
            claimed[subject] = [c[0] for c in cand]
        if len(claimed) < self.min_subjects or not pairs:
            return None

        # Pre-extracted result columns: one C-level gather per (subject,
        # trigger) run, in the same subject-slice order the grouped path's
        # batched conditions would append in.  On a ``_D_RESULT`` frame the
        # whole-batch result column already exists inside the decoded frame.
        if aggregating:
            res = cols.results() if cols is not None else None
            for subject, tids in claimed.items():
                acc_cols = [aggregating[t] for t in tids if t in aggregating]
                if not acc_cols:
                    continue
                sidx = by_subject[subject]
                column = ([res[i] for i in sidx] if res is not None
                          else [_result_of(batch[i]) for i in sidx])
                for col in acc_cols:
                    col.extend(column)

        rows = list(pairs.values())
        n_rows = len(rows)
        counts = np.fromiter((r[1] for r in rows), np.int32, n_rows)
        expected = np.fromiter((r[2] for r in rows), np.int32, n_rows)
        lens = np.fromiter((r[3] for r in rows), np.int64, n_rows)
        # The routed event batch as the kernel sees it is contiguous runs of
        # trigger-row ids (−1 would be padding; none is needed here) — the
        # row-id expansion lives next to the kernel.
        new_counts, fired = self._join_segments(lens, counts, expected,
                                                self._join)
        if fired.any():  # pragma: no cover - screening guarantees this
            raise AssertionError("vector join plane screening let a fire through")
        total = 0
        for i, (tid, row) in enumerate(pairs.items()):
            ctx = row[0]
            ctx["count"] = int(new_counts[i])
            column = aggregating.get(tid)
            if column:
                results = ctx.get("results") or []
                results.extend(column)
                ctx["results"] = results
            total += row[3]
        stats.activations += total
        self.calls += 1
        self.events += int(lens.sum())

        if len(claimed) == len(by_subject):
            # Fully claimed: nothing materializes even on the columnar path.
            return (ids if cols is None else list(ids)), []
        evs = cols.events() if cols is not None else batch
        return ([ids[i] for i, s in enumerate(subjects) if s in claimed],
                [evs[i] for i, s in enumerate(subjects) if s not in claimed])
