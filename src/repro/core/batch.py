"""Vector join plane: batched aggregation-condition evaluation as array ops.

The worker's batch plane evaluates conditions over ``(subject, type)``
slices.  This module is the fully-vectorized tier above that: a consumed
batch whose subjects route to pure aggregation joins (``counter`` with
``aggregate=False`` and no ``exactly_once`` dedup) that provably cannot fire
within the batch (``count + batch share < expected``) reduces to *counting*
— no action runs, no per-event state changes except the counters.

``triage`` therefore never touches individual events in Python: the batch is
histogrammed C-level (one list comprehension + ``Counter``), each distinct
subject is screened against its compiled dispatch entries, and all claimed
subjects are folded into one one-hot segmented sum over the routed event
batch — the ``event_join`` kernel (Pallas on TPU, jitted-jnp or ``bincount``
on CPU; see ``kernels.event_join.dispatch``).  The Table-1 join hot loop
becomes O(batch) array ops plus O(distinct subjects) Python.

Everything else — slices that would cross a threshold, dedup, timeouts,
failures, aggregating joins, non-join conditions — is returned as leftover
for the worker's per-trigger batched/scalar path, which owns the exact fire
semantics.  The screening is the correctness boundary: the kernel only ever
sees slices whose outcome is pure counting, so parity with the scalar
interpreter is by construction.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Callable, List, Optional, Sequence, Tuple

try:  # numpy is the plane's only hard dependency; degrade to None without it
    import numpy as np
except ImportError:  # pragma: no cover - numpy is in the base image
    np = None

from .events import TYPE_FAILURE, TYPE_TIMEOUT, CloudEvent

TriageResult = Tuple[List[str], List[CloudEvent]]  # (handled ids, leftover)


class VectorJoinPlane:
    """Batch-level accelerator for pure-counting join batches."""

    def __init__(self, backend: Optional[str] = None, min_subjects: int = 2):
        if np is None:
            raise RuntimeError("VectorJoinPlane requires numpy")
        from ..kernels.event_join.dispatch import resolve_join_backend

        self.backend, self._join = resolve_join_backend(backend)
        if self._join is None:
            raise RuntimeError("join backend disabled")
        # Below this many claimable subjects the per-trigger batched
        # conditions beat array assembly.
        self.min_subjects = min_subjects
        self.calls = 0
        self.events = 0

    def triage(self, batch: List[CloudEvent],
               entries_for: Callable[[str], Sequence[Any]],
               stats) -> Optional[TriageResult]:
        """Claim and evaluate the pure-counting share of a consumed batch.

        Returns ``(handled_event_ids, leftover_events)`` — the handled events
        have been fully accounted (counters advanced, activations counted)
        and only need committing; the leftovers carry every event the exact
        path must see.  Returns ``None`` when the batch isn't worth
        vectorizing (mixed types, failure/timeout slices, too few claimable
        subjects) — the caller then processes the whole batch normally.
        """
        etype = batch[0].type
        if len({e.type for e in batch}) != 1:
            return None
        if etype == TYPE_FAILURE or etype == TYPE_TIMEOUT:
            return None
        ids = [e.id for e in batch]
        if len(set(ids)) != len(ids):
            # A re-published duplicate inside the batch: counting the copies
            # would double-count the join.  The grouped path's in-flight set
            # dedups exactly (§3.4), so leave the whole batch to it.
            return None
        histogram = Counter([e.subject for e in batch])
        # tid -> [ctx, count0, expected, events_in_batch]
        pairs: dict = {}
        handled: set = set()
        for subject, m in histogram.items():
            entries = entries_for(subject)
            if not entries:
                continue  # unknown subject: worker's drop-count path
            cand = []
            for entry in entries:
                if not entry.matches(etype):
                    continue
                trg = entry.trg
                cspec = entry.cspec
                if (entry.cname != "counter" or cspec.get("aggregate", True)
                        or cspec.get("exactly_once")):
                    cand = None  # needs per-event work → exact path
                    break
                ctx = entry.ctx
                expected = int(ctx.get("expected", cspec.get("expected", 1)))
                tid = trg.trigger_id
                prior = pairs.get(tid)
                count0 = prior[1] if prior is not None else ctx.get("count", 0)
                acc = prior[3] if prior is not None else 0
                if not isinstance(count0, int) or count0 + acc + m >= expected:
                    cand = None  # could fire inside this batch
                    break
                cand.append((tid, ctx, count0, expected))
            if not cand:  # ineligible, or zero enabled candidates (DLQ path)
                continue
            for tid, ctx, count0, expected in cand:
                prior = pairs.get(tid)
                if prior is None:
                    pairs[tid] = [ctx, count0, expected, m]
                else:
                    prior[3] += m
            handled.add(subject)
        if len(handled) < self.min_subjects or not pairs:
            return None

        rows = list(pairs.values())
        n_rows = len(rows)
        counts = np.fromiter((r[1] for r in rows), np.int32, n_rows)
        expected = np.fromiter((r[2] for r in rows), np.int32, n_rows)
        lens = np.fromiter((r[3] for r in rows), np.int64, n_rows)
        # The routed event batch as the kernel sees it: one trigger-row id
        # per event (−1 would be padding; none is needed here).
        event_rows = np.repeat(np.arange(n_rows, dtype=np.int32), lens)
        new_counts, fired = self._join(event_rows, counts, expected)
        if fired.any():  # pragma: no cover - screening guarantees this
            raise AssertionError("vector join plane screening let a fire through")
        total = 0
        for i, row in enumerate(rows):
            row[0]["count"] = int(new_counts[i])
            total += row[3]
        stats.activations += total
        self.calls += 1
        self.events += int(lens.sum())

        if len(handled) == len(histogram):
            return ids, []
        return ([e.id for e in batch if e.subject in handled],
                [e for e in batch if e.subject not in handled])
