"""Condition registry (paper §3.2: Conditions are user-defined active rules).

Conditions are referenced by name + JSON params so triggers stay serializable.
A condition is ``fn(context, event, params) -> bool``; it may mutate the
context (stateful composite event detection: counters, aggregation) and MUST
be idempotent w.r.t. re-delivered events (§3.4) — the built-in aggregators
offer an ``exactly_once`` param that dedups by event id inside the context.

Batched-condition protocol (the worker's batch plane)
-----------------------------------------------------
A condition may additionally register a *batched* implementation
``fn_batch(ctx, events, params) -> fire_index | None`` via
``register_condition(name, fn, batched=fn_batch)``.  The contract:

* ``events`` is a non-empty, **type-uniform** slice of CloudEvents addressed
  to this trigger, in arrival order (the worker groups each consumed batch
  by ``(subject, type)``).
* The batched fn must be semantically identical to folding the scalar fn
  over the slice: it returns ``None`` if no event fires (the whole slice is
  consumed and the context reflects it), or the smallest index ``i`` at
  which the scalar fn would have returned True — with the context reflecting
  consumption of ``events[:i + 1]`` only.  The worker then runs the action
  with ``events[i]`` and re-enters the batched fn on the remaining slice.
* Anything the batched fn cannot replicate exactly (``exactly_once`` dedup
  under redelivery, timeout handling) falls back to sweeping the scalar fn
  over the slice via ``scalar_sweep`` — correctness first, speed second.

Fire-run protocol (the worker's action plane)
---------------------------------------------
The batched protocol above still re-enters the condition once per *fire* —
fine for sparse joins, but a trigger that fires on (nearly) every event
(the Table-1 noop scenario) degenerates back to one Python round-trip per
event.  A condition may therefore also register a *fire-run* implementation
``fn_run(ctx, events, params) -> list[int] | None`` via
``register_condition(name, fn, batched=..., fire_run=fn_run)``:

* It consumes the **whole** type-uniform slice in one call and returns the
  ascending positions at which the scalar fn would have returned True, with
  the context reflecting full consumption — i.e. it collapses the entire
  evaluate→fire→re-enter loop into one call plus one batched action.
* Returning ``None`` declines the run (``exactly_once`` dedup, timeouts,
  anything needing per-event care) and the worker falls back to the
  per-fire batched/scalar path above.  A fire-run fn must decline *before*
  mutating the context — the fallback re-evaluates the same slice.
* The worker only takes this path for non-transient triggers whose action
  has a batched implementation (``actions.BATCHED_ACTIONS``): transient
  triggers must stop at their first fire, and scalar-only actions keep the
  exact condition/action interleaving of the per-fire path.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional

from .events import TYPE_FAILURE, TYPE_TIMEOUT, CloudEvent

ConditionFn = Callable[[Any, CloudEvent, Dict[str, Any]], bool]
BatchedConditionFn = Callable[[Any, List[CloudEvent], Dict[str, Any]], Optional[int]]
FireRunConditionFn = Callable[[Any, List[CloudEvent], Dict[str, Any]],
                              Optional[List[int]]]

CONDITIONS: Dict[str, ConditionFn] = {}
#: Opt-in batched implementations, keyed like ``CONDITIONS``.
BATCHED_CONDITIONS: Dict[str, BatchedConditionFn] = {}
#: Opt-in fire-run implementations (whole-slice fire positions), keyed alike.
FIRE_RUN_CONDITIONS: Dict[str, FireRunConditionFn] = {}


def condition(name: str, batched: Optional[BatchedConditionFn] = None,
              fire_run: Optional[FireRunConditionFn] = None
              ) -> Callable[[ConditionFn], ConditionFn]:
    def deco(fn: ConditionFn) -> ConditionFn:
        register_condition(name, fn, batched=batched, fire_run=fire_run)
        return fn

    return deco


def register_condition(name: str, fn: ConditionFn,
                       batched: Optional[BatchedConditionFn] = None,
                       fire_run: Optional[FireRunConditionFn] = None) -> None:
    """Third-party extension point (paper: extensible at all levels).

    ``batched`` opts the condition into the worker's batch plane, ``fire_run``
    additionally into the action plane; without them the worker degrades to
    the scalar / per-fire path for this condition's slices."""
    CONDITIONS[name] = fn
    if batched is not None:
        BATCHED_CONDITIONS[name] = batched
    else:
        # re-registering without a batched impl must not leave a stale one
        BATCHED_CONDITIONS.pop(name, None)
    if fire_run is not None:
        FIRE_RUN_CONDITIONS[name] = fire_run
    else:
        FIRE_RUN_CONDITIONS.pop(name, None)


def batched_condition(name: str) -> Callable[[BatchedConditionFn], BatchedConditionFn]:
    """Attach a batched implementation to an already-registered condition."""
    def deco(fn: BatchedConditionFn) -> BatchedConditionFn:
        BATCHED_CONDITIONS[name] = fn
        return fn

    return deco


def fire_run_condition(name: str) -> Callable[[FireRunConditionFn], FireRunConditionFn]:
    """Attach a fire-run implementation to an already-registered condition."""
    def deco(fn: FireRunConditionFn) -> FireRunConditionFn:
        FIRE_RUN_CONDITIONS[name] = fn
        return fn

    return deco


def scalar_sweep(fn: ConditionFn, ctx, events: List[CloudEvent],
                 params: Dict[str, Any]) -> Optional[int]:
    """Reference fold of a scalar condition over a slice — the semantics every
    batched implementation must match, and the fallback they delegate to."""
    for i, event in enumerate(events):
        if fn(ctx, event, params):
            return i
    return None


def _result_of(event: CloudEvent) -> Any:
    if isinstance(event.data, dict) and "result" in event.data:
        return event.data["result"]
    return event.data


@condition("true")
def _true(ctx, event, params) -> bool:
    return True


@batched_condition("true")
def _true_batch(ctx, events, params) -> Optional[int]:
    return 0


@fire_run_condition("true")
def _true_run(ctx, events, params) -> Optional[List[int]]:
    return list(range(len(events)))


@condition("false")
def _false(ctx, event, params) -> bool:
    return False


@batched_condition("false")
def _false_batch(ctx, events, params) -> Optional[int]:
    return None


@fire_run_condition("false")
def _false_run(ctx, events, params) -> Optional[List[int]]:
    return []


def _seen_set(ctx) -> set:
    """The exactly-once dedup index as an in-memory set.

    Checkpoints serialize it as a sorted list (``context.jsonable``); a
    recovered context therefore holds a list, converted back on first use.
    Kept as a set in memory so 10k-event joins don't scan a list per event
    (the old O(n²) behavior)."""
    seen = ctx.get("seen_ids")
    if isinstance(seen, set):
        return seen
    seen = set(seen) if seen else set()
    ctx["seen_ids"] = seen
    return seen


def _dedup(ctx, event, params) -> bool:
    """Returns True if this event was already counted (skip it)."""
    if not params.get("exactly_once", False):
        return False
    seen = _seen_set(ctx)
    if event.id in seen:
        return True
    seen.add(event.id)
    ctx["seen_ids"] = seen  # same object; assignment marks the key dirty
    return False


@condition("counter")
def _counter(ctx, event, params) -> bool:
    """Composite-event aggregation: fire after ``expected`` activations.

    ``expected`` is read from the context first so an upstream Map action can
    set it dynamically via introspection (§5.1); falls back to params.
    Aggregates each event's result into ``ctx['results']`` unless
    ``aggregate=False`` (pure join counters for the Table 1 load test).
    """
    if event.type == TYPE_FAILURE:
        # failures never satisfy a join; a companion failure trigger handles them
        ctx["failures"] = ctx.get("failures", 0) + 1
        return False
    if _dedup(ctx, event, params):
        return ctx.get("count", 0) >= int(ctx.get("expected", params.get("expected", 1)))
    cnt = ctx.get("count", 0) + 1
    ctx["count"] = cnt
    if params.get("aggregate", True):
        results = ctx.get("results") or []
        results.append(_result_of(event))
        ctx["results"] = results
    expected = int(ctx.get("expected", params.get("expected", 1)))
    if cnt >= expected:
        # snapshot for the action, then optionally reset so persistent join
        # triggers can be re-fired (ASL loops, FL rounds)
        ctx["fired_results"] = ctx.get("results") or []
        if params.get("reset_on_fire"):
            ctx["count"] = 0
            ctx["results"] = []
            if params.get("exactly_once"):
                ctx["seen_ids"] = set()
        return True
    return False


def _count_slice(ctx, events, cnt: int, threshold: int,
                 aggregate: bool) -> Optional[int]:
    """Shared counting core of the batched aggregators: advance ``count``
    over the slice (appending results when aggregating) and return the fire
    index where the running count reaches ``threshold``, or None.  When the
    count is already at/over the threshold the first event fires — matching
    the scalar aggregators, which keep returning True once satisfied."""
    n = len(events)
    if cnt + n < threshold:
        ctx["count"] = cnt + n
        if aggregate:
            results = ctx.get("results") or []
            results.extend(_result_of(e) for e in events)
            ctx["results"] = results
        return None
    fire_idx = max(0, threshold - cnt - 1)
    take = fire_idx + 1
    ctx["count"] = cnt + take
    if aggregate:
        results = ctx.get("results") or []
        results.extend(_result_of(e) for e in events[:take])
        ctx["results"] = results
    return fire_idx


@batched_condition("counter")
def _counter_batch(ctx, events, params) -> Optional[int]:
    if events[0].type == TYPE_FAILURE:
        # type-uniform slice: every event is a failure notification
        ctx["failures"] = ctx.get("failures", 0) + len(events)
        return None
    if params.get("exactly_once", False):
        # redelivery dedup interleaves with counting — scalar is the oracle
        return scalar_sweep(_counter, ctx, events, params)
    expected = int(ctx.get("expected", params.get("expected", 1)))
    fire_idx = _count_slice(ctx, events, ctx.get("count", 0), expected,
                            params.get("aggregate", True))
    if fire_idx is None:
        return None
    ctx["fired_results"] = ctx.get("results") or []
    if params.get("reset_on_fire"):
        ctx["count"] = 0
        ctx["results"] = []
    return fire_idx


@fire_run_condition("counter")
def _counter_run(ctx, events, params) -> Optional[List[int]]:
    """Whole-slice counter evaluation: every fire position in one call.

    Exactly the scalar fold collapsed: counts advance arithmetically, results
    aggregate in C-level comprehensions, and ``fired_results`` lands on the
    value the *last* fire's snapshot would have left behind."""
    if events[0].type == TYPE_FAILURE:
        # type-uniform slice: every event is a failure notification
        ctx["failures"] = ctx.get("failures", 0) + len(events)
        return []
    if params.get("exactly_once", False):
        return None  # redelivery dedup interleaves with counting
    cnt = ctx.get("count", 0)
    expected = int(ctx.get("expected", params.get("expected", 1)))
    n = len(events)
    aggregate = params.get("aggregate", True)
    first = max(0, expected - cnt - 1)
    if first >= n or not params.get("reset_on_fire"):
        # no reset involved: counts and results simply advance over the slice
        ctx["count"] = cnt + n
        if aggregate:
            results = ctx.get("results") or []
            results.extend(_result_of(e) for e in events)
            ctx["results"] = results
        if first >= n:  # the threshold is not reached inside this slice
            return []
        # once satisfied the scalar fn keeps returning True: the tail fires
        ctx["fired_results"] = ctx.get("results") or []
        return list(range(first, n))
    fires = list(range(first, n, max(1, expected)))
    last = fires[-1]
    ctx["count"] = n - last - 1  # events consumed since the last reset
    if aggregate:
        if len(fires) == 1:
            snapshot = ctx.get("results") or []
        else:
            snapshot = []
        snapshot = snapshot + [_result_of(e) for e in events[
            (fires[-2] + 1 if len(fires) > 1 else 0):last + 1]]
        ctx["fired_results"] = snapshot
        ctx["results"] = [_result_of(e) for e in events[last + 1:]]
    else:
        # the last fire snapshots pre-reset results: the pre-run value for a
        # single fire, [] (reset by the previous fire) for multiple
        ctx["fired_results"] = (ctx.get("results") or []) if len(fires) == 1 else []
        ctx["results"] = []
    return fires


@condition("threshold_join")
def _threshold_join(ctx, event, params) -> bool:
    """Federated-learning style aggregation (§5.4): fire when ``fraction`` of
    the expected events arrived, or immediately on a timeout event — so
    stragglers and failed clients cannot hang the workflow."""
    if event.type == TYPE_TIMEOUT:
        ctx["timed_out"] = True
        return ctx.get("count", 0) >= int(params.get("min_events", 1))
    if event.type == TYPE_FAILURE:
        ctx["failures"] = ctx.get("failures", 0) + 1
        return False
    if _dedup(ctx, event, params):
        return False
    cnt = ctx.get("count", 0) + 1
    ctx["count"] = cnt
    results = ctx.get("results") or []
    results.append(_result_of(event))
    ctx["results"] = results
    expected = int(ctx.get("expected", params.get("expected", 1)))
    frac = float(params.get("fraction", 1.0))
    return cnt >= max(1, math.ceil(expected * frac))


@batched_condition("threshold_join")
def _threshold_join_batch(ctx, events, params) -> Optional[int]:
    et = events[0].type
    if et == TYPE_FAILURE:
        ctx["failures"] = ctx.get("failures", 0) + len(events)
        return None
    if et == TYPE_TIMEOUT or params.get("exactly_once", False):
        return scalar_sweep(_threshold_join, ctx, events, params)
    expected = int(ctx.get("expected", params.get("expected", 1)))
    frac = float(params.get("fraction", 1.0))
    threshold = max(1, math.ceil(expected * frac))
    return _count_slice(ctx, events, ctx.get("count", 0), threshold, True)


@fire_run_condition("threshold_join")
def _threshold_join_run(ctx, events, params) -> Optional[List[int]]:
    et = events[0].type
    if et == TYPE_FAILURE:
        ctx["failures"] = ctx.get("failures", 0) + len(events)
        return []
    if et == TYPE_TIMEOUT or params.get("exactly_once", False):
        return None
    cnt = ctx.get("count", 0)
    expected = int(ctx.get("expected", params.get("expected", 1)))
    threshold = max(1, math.ceil(expected * float(params.get("fraction", 1.0))))
    n = len(events)
    ctx["count"] = cnt + n
    results = ctx.get("results") or []
    results.extend(_result_of(e) for e in events)
    ctx["results"] = results
    first = max(0, threshold - cnt - 1)
    # the scalar fn keeps returning True once satisfied: the tail fires
    return list(range(first, n)) if first < n else []


_OPS = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "is_present": lambda a, b: a is not None,
    "str_eq": lambda a, b: str(a) == str(b),
    "bool_eq": lambda a, b: bool(a) == bool(b),
}


def _extract(data: Any, var: str) -> Any:
    """ASL-ish '$.a.b' JSON-path extraction."""
    cur = data
    for part in var.lstrip("$.").split("."):
        if not part:
            continue
        if isinstance(cur, dict):
            cur = cur.get(part)
        else:
            return None
    return cur


@condition("rules")
def _rules(ctx, event, params) -> bool:
    """ASF Choice-state rules (§5.2): first matching rule decides the next
    state, recorded in ``ctx['matched_next']`` for the action to read."""
    data = event.data if isinstance(event.data, dict) else {"result": event.data}
    for rule in params.get("rules", []):
        val = _extract(data, rule["var"])
        try:
            ok = _OPS[rule["op"]](val, rule.get("value"))
        except TypeError:
            ok = False
        if ok:
            ctx["matched_next"] = rule["next"]
            return True
    if params.get("default"):
        ctx["matched_next"] = params["default"]
        return True
    return False


@condition("event_type")
def _event_type(ctx, event, params) -> bool:
    return event.type == params.get("type", "")


@condition("python")
def _python(ctx, event, params) -> bool:
    """Escape hatch for programmable conditions: a restricted expression over
    ``event`` / ``context`` (extensibility demo; used in tests)."""
    expr = params.get("expr", "True")
    return bool(
        eval(  # noqa: S307 - deliberate, restricted namespace
            expr,
            {"__builtins__": {"len": len, "min": min, "max": max, "sum": sum}},
            {"event": event, "context": ctx, "data": event.data},
        )
    )
