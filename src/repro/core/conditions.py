"""Condition registry (paper §3.2: Conditions are user-defined active rules).

Conditions are referenced by name + JSON params so triggers stay serializable.
A condition is ``fn(context, event, params) -> bool``; it may mutate the
context (stateful composite event detection: counters, aggregation) and MUST
be idempotent w.r.t. re-delivered events (§3.4) — the built-in aggregators
offer an ``exactly_once`` param that dedups by event id inside the context.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

from .events import TYPE_FAILURE, TYPE_TIMEOUT, CloudEvent

ConditionFn = Callable[[Any, CloudEvent, Dict[str, Any]], bool]

CONDITIONS: Dict[str, ConditionFn] = {}


def condition(name: str) -> Callable[[ConditionFn], ConditionFn]:
    def deco(fn: ConditionFn) -> ConditionFn:
        CONDITIONS[name] = fn
        return fn

    return deco


def register_condition(name: str, fn: ConditionFn) -> None:
    """Third-party extension point (paper: extensible at all levels)."""
    CONDITIONS[name] = fn


def _result_of(event: CloudEvent) -> Any:
    if isinstance(event.data, dict) and "result" in event.data:
        return event.data["result"]
    return event.data


@condition("true")
def _true(ctx, event, params) -> bool:
    return True


@condition("false")
def _false(ctx, event, params) -> bool:
    return False


def _dedup(ctx, event, params) -> bool:
    """Returns True if this event was already counted (skip it)."""
    if not params.get("exactly_once", False):
        return False
    seen = ctx.get("seen_ids") or []
    if event.id in seen:
        return True
    seen.append(event.id)
    ctx["seen_ids"] = seen
    return False


@condition("counter")
def _counter(ctx, event, params) -> bool:
    """Composite-event aggregation: fire after ``expected`` activations.

    ``expected`` is read from the context first so an upstream Map action can
    set it dynamically via introspection (§5.1); falls back to params.
    Aggregates each event's result into ``ctx['results']`` unless
    ``aggregate=False`` (pure join counters for the Table 1 load test).
    """
    if event.type == TYPE_FAILURE:
        # failures never satisfy a join; a companion failure trigger handles them
        ctx["failures"] = ctx.get("failures", 0) + 1
        return False
    if _dedup(ctx, event, params):
        return ctx.get("count", 0) >= int(ctx.get("expected", params.get("expected", 1)))
    cnt = ctx.get("count", 0) + 1
    ctx["count"] = cnt
    if params.get("aggregate", True):
        results = ctx.get("results") or []
        results.append(_result_of(event))
        ctx["results"] = results
    expected = int(ctx.get("expected", params.get("expected", 1)))
    if cnt >= expected:
        # snapshot for the action, then optionally reset so persistent join
        # triggers can be re-fired (ASL loops, FL rounds)
        ctx["fired_results"] = ctx.get("results") or []
        if params.get("reset_on_fire"):
            ctx["count"] = 0
            ctx["results"] = []
            if params.get("exactly_once"):
                ctx["seen_ids"] = []
        return True
    return False


@condition("threshold_join")
def _threshold_join(ctx, event, params) -> bool:
    """Federated-learning style aggregation (§5.4): fire when ``fraction`` of
    the expected events arrived, or immediately on a timeout event — so
    stragglers and failed clients cannot hang the workflow."""
    if event.type == TYPE_TIMEOUT:
        ctx["timed_out"] = True
        return ctx.get("count", 0) >= int(params.get("min_events", 1))
    if event.type == TYPE_FAILURE:
        ctx["failures"] = ctx.get("failures", 0) + 1
        return False
    if _dedup(ctx, event, params):
        return False
    cnt = ctx.get("count", 0) + 1
    ctx["count"] = cnt
    results = ctx.get("results") or []
    results.append(_result_of(event))
    ctx["results"] = results
    expected = int(ctx.get("expected", params.get("expected", 1)))
    frac = float(params.get("fraction", 1.0))
    return cnt >= max(1, math.ceil(expected * frac))


_OPS = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "is_present": lambda a, b: a is not None,
    "str_eq": lambda a, b: str(a) == str(b),
    "bool_eq": lambda a, b: bool(a) == bool(b),
}


def _extract(data: Any, var: str) -> Any:
    """ASL-ish '$.a.b' JSON-path extraction."""
    cur = data
    for part in var.lstrip("$.").split("."):
        if not part:
            continue
        if isinstance(cur, dict):
            cur = cur.get(part)
        else:
            return None
    return cur


@condition("rules")
def _rules(ctx, event, params) -> bool:
    """ASF Choice-state rules (§5.2): first matching rule decides the next
    state, recorded in ``ctx['matched_next']`` for the action to read."""
    data = event.data if isinstance(event.data, dict) else {"result": event.data}
    for rule in params.get("rules", []):
        val = _extract(data, rule["var"])
        try:
            ok = _OPS[rule["op"]](val, rule.get("value"))
        except TypeError:
            ok = False
        if ok:
            ctx["matched_next"] = rule["next"]
            return True
    if params.get("default"):
        ctx["matched_next"] = params["default"]
        return True
    return False


@condition("event_type")
def _event_type(ctx, event, params) -> bool:
    return event.type == params.get("type", "")


@condition("python")
def _python(ctx, event, params) -> bool:
    """Escape hatch for programmable conditions: a restricted expression over
    ``event`` / ``context`` (extensibility demo; used in tests)."""
    expr = params.get("expr", "True")
    return bool(
        eval(  # noqa: S307 - deliberate, restricted namespace
            expr,
            {"__builtins__": {"len": len, "min": min, "max": max, "sum": sum}},
            {"event": event, "context": ctx, "data": event.data},
        )
    )
