"""Simulated serverless-function backend (the data plane's task executor).

In the paper, trigger Actions asynchronously invoke cloud functions (IBM CF /
AWS Lambda) which later emit termination CloudEvents.  Offline we model this
with a thread pool: ``invoke`` schedules a registered callable; on completion
a ``termination.success`` event (with the result) — or ``termination.failure``
(with the error) — is published to the workflow's event stream.

``inline=True`` executes in the caller thread (deterministic single-threaded
orchestration-overhead benchmarks, isolating trigger overhead from threading).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional

from .events import failure_event, termination_event
from .eventstore import EventStore


class FunctionBackend:
    def __init__(self, event_store: EventStore, max_workers: int = 64, inline: bool = False):
        self.event_store = event_store
        self.inline = inline
        self._pool: Optional[ThreadPoolExecutor] = None
        self._max_workers = max_workers
        self.registry: Dict[str, Callable[[Any], Any]] = {}
        self.invocations = 0
        self._lock = threading.Lock()

    # -- registry --------------------------------------------------------------
    def register(self, name: str, fn: Callable[[Any], Any]) -> None:
        self.registry[name] = fn

    def function(self, name: str) -> Callable[[Callable], Callable]:
        def deco(fn: Callable) -> Callable:
            self.register(name, fn)
            return fn

        return deco

    # -- invocation --------------------------------------------------------------
    def _run(self, workflow: str, fn_name: str, args: Any, subject: str, delay: float) -> None:
        try:
            if delay > 0:
                time.sleep(delay)
            result = self.registry[fn_name](args)
            self.event_store.publish(workflow, termination_event(subject, result=result, fn=fn_name))
        except Exception as exc:  # noqa: BLE001 - failures become failure events
            self.event_store.publish(workflow, failure_event(subject, error=str(exc), fn=fn_name))

    def invoke(self, workflow: str, fn_name: str, args: Any, subject: str, delay: float = 0.0) -> None:
        with self._lock:
            self.invocations += 1
        if self.inline:
            self._run(workflow, fn_name, args, subject, delay)
            return
        if self._pool is None:
            with self._lock:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(max_workers=self._max_workers,
                                                    thread_name_prefix="tf-fn")
        self._pool.submit(self._run, workflow, fn_name, args, subject, delay)

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


class TimerSource:
    """Timer event source (Wait states §5.2, FL round timeouts §5.4)."""

    def __init__(self, event_store: EventStore):
        self.event_store = event_store
        self._timers: list = []

    def after(self, workflow: str, delay: float, event) -> threading.Timer:
        t = threading.Timer(delay, self.event_store.publish, args=(workflow, event))
        t.daemon = True
        t.start()
        self._timers.append(t)
        return t

    def cancel_all(self) -> None:
        for t in self._timers:
            t.cancel()
        self._timers.clear()
