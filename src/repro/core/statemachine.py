"""§5.2 — Amazon States Language (ASL) state machines on top of triggers.

Supported state types: Task, Pass, Choice, Parallel, Map, Wait, Succeed, Fail.
Every state transition becomes a trigger (paper Def. 3).  Parallel/Map states
run *sub-state machines* identified by a unique scope tag; sub-machine
termination is itself an event (substitution principle, Def. 4), so state
machines nest seamlessly.  Map sub-machines are deployed **dynamically** at
execution time because the iterator width is unknown until then (§5.2), via
dynamic trigger creation through the Context; the map join's expected count is
set by introspection.  State outputs chain to the next state's input through
the termination events.  Choice rules live in the trigger *condition*.

ASL loops (Choice back-edges) are supported: triggers are persistent and join
counters reset on fire.

Subjects:   ``enter|<scope>|<state>``  state activation (carries the input)
            ``done|<scope>|<state>``   state termination (carries the output)
            ``end|<scope>``            sub-state-machine termination
            ``mapend|<scope>|<state>`` per-item terminations of a Map state
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

from .actions import register_pyfunc
from .events import termination_event
from .service import Triggerflow
from .triggers import Trigger, make_trigger

# Deployed machine registry: pyfunc actions resolve definitions at runtime.
_MACHINES: Dict[str, "StateMachine"] = {}
# Wait states / timeouts need the service's timer source, keyed by workflow.
_TIMERS: Dict[str, Any] = {}
_scope_counter = itertools.count()


def _result_of(event) -> Any:
    if isinstance(event.data, dict) and "result" in event.data:
        return event.data["result"]
    return event.data


class StateMachine:
    def __init__(self, definition: Dict[str, Any], sm_id: Optional[str] = None):
        self.definition = definition
        self.sm_id = sm_id or f"sm-{next(_scope_counter):x}"
        _MACHINES[self.sm_id] = self

    # -- deployment --------------------------------------------------------------
    def deploy(self, tf: Triggerflow, workflow: str) -> None:
        tf.create_workflow(workflow, {"kind": "statemachine", "sm_id": self.sm_id})
        _TIMERS[workflow] = tf.timers
        triggers = self._compile(workflow, self.definition, scope="root")
        triggers.append(make_trigger(
            "$init",
            action={"name": "pyfunc", "func": "asl.enter_start", "sm": self.sm_id,
                    "workflow": workflow, "scope": "root",
                    "start_at": self.definition["StartAt"]},
            trigger_id=f"{workflow}/root/$init", transient=False))
        triggers.append(make_trigger(
            "end|root",
            action={"name": "workflow_end", "pass_result": True},
            trigger_id=f"{workflow}/root/$done", transient=False))
        tf.add_trigger(workflow, triggers)

    def _compile(self, workflow: str, definition: Dict[str, Any],
                 scope: str) -> List[Trigger]:
        triggers: List[Trigger] = []
        for name, state in definition["States"].items():
            triggers.extend(self._compile_state(workflow, name, state, scope))
        return triggers

    def _compile_state(self, workflow: str, name: str, state: Dict[str, Any],
                       scope: str) -> List[Trigger]:
        stype = state["Type"]
        triggers: List[Trigger] = []
        enter_subject = f"enter|{scope}|{name}"
        done_subject = f"done|{scope}|{name}"
        base = {"sm": self.sm_id, "workflow": workflow, "scope": scope, "state": name}

        if stype == "Choice":
            rules = [{"var": r.get("Variable", "$.result"), "op": r["Op"],
                      "value": r.get("Value"), "next": r["Next"]}
                     for r in state.get("Choices", [])]
            triggers.append(make_trigger(
                enter_subject,
                condition={"name": "rules", "rules": rules,
                           "default": state.get("Default")},
                action={"name": "pyfunc", "func": "asl.choice", **base},
                trigger_id=f"{workflow}/{scope}/{name}", transient=False))
            return triggers

        # the enter trigger executes the state
        triggers.append(make_trigger(
            enter_subject,
            action={"name": "pyfunc", "func": "asl.exec_state", **base},
            trigger_id=f"{workflow}/{scope}/{name}", transient=False))

        needs_done_router = stype in ("Task", "Wait", "Parallel", "Map")
        if stype == "Parallel":
            branches = state["Branches"]
            for i, br in enumerate(branches):
                triggers.extend(self._compile(workflow, br, f"{scope}/{name}[{i}]"))
            triggers.append(make_trigger(
                [f"end|{scope}/{name}[{i}]" for i in range(len(branches))],
                condition={"name": "counter", "expected": len(branches),
                           "reset_on_fire": True},
                action={"name": "pyfunc", "func": "asl.join_done", **base},
                trigger_id=f"{workflow}/{scope}/{name}/join", transient=False))
        elif stype == "Map":
            # per-item sub-machines are deployed dynamically at exec time;
            # the join trigger is static, its expected count set by introspection
            triggers.append(make_trigger(
                f"mapend|{scope}|{name}",
                condition={"name": "counter", "expected": 10 ** 9,
                           "reset_on_fire": True},
                action={"name": "pyfunc", "func": "asl.join_done", **base},
                trigger_id=f"{workflow}/{scope}/{name}/join", transient=False))
        if needs_done_router:
            triggers.append(make_trigger(
                done_subject,
                action={"name": "pyfunc", "func": "asl.route_next", **base},
                trigger_id=f"{workflow}/{scope}/{name}/done", transient=False))
        if stype not in ("Task", "Wait", "Parallel", "Map", "Pass", "Succeed", "Fail"):
            raise ValueError(f"unsupported state type {stype}")
        return triggers

    def run(self, tf: Triggerflow, workflow: str, data: Any = None,
            timeout: float = 60.0) -> Any:
        tf.init_workflow(workflow, data=data)
        return tf.run_until_complete(workflow, timeout=timeout)


# -- runtime pyfuncs ---------------------------------------------------------------
def _state_def(params) -> Dict[str, Any]:
    """Walk the definition along the scope path root/S[i]/T[j]… ('#k' execution
    counters in Map scopes are ignored for definition lookup)."""
    node: Any = _MACHINES[params["sm"]].definition
    scope = params["scope"]
    if scope != "root":
        for part in scope.split("/")[1:]:
            sname = part.split("[")[0].split("#")[0]
            idx = int(part.split("[")[1][:-1])
            st = node["States"][sname]
            node = st["Branches"][idx] if st["Type"] == "Parallel" else st["Iterator"]
    return node["States"][params["state"]]


def _enter_start(ctx, event, params) -> None:
    data = _result_of(event) if isinstance(event.data, dict) else event.data
    ctx.produce(termination_event(
        f"enter|{params['scope']}|{params['start_at']}", result=data))


def _route(ctx, params, state: Dict[str, Any], result: Any) -> None:
    if state.get("End") or "Next" not in state:
        ctx.produce(termination_event(f"end|{params['scope']}", result=result))
    else:
        ctx.produce(termination_event(
            f"enter|{params['scope']}|{state['Next']}", result=result))


def _exec_state(ctx, event, params) -> None:
    state = _state_def(params)
    stype = state["Type"]
    inp = _result_of(event)
    scope, name, wf = params["scope"], params["state"], params["workflow"]
    if stype == "Task":
        ctx.invoke(state["Resource"], inp, f"done|{scope}|{name}",
                   delay=state.get("SimulatedDelay", 0.0))
    elif stype == "Pass":
        _route(ctx, params, state, state.get("Result", inp))
    elif stype == "Wait":
        _TIMERS[wf].after(wf, float(state.get("Seconds", 0)),
                          termination_event(f"done|{scope}|{name}", result=inp))
    elif stype == "Parallel":
        for i, br in enumerate(state["Branches"]):
            ctx.produce(termination_event(
                f"enter|{scope}/{name}[{i}]|{br['StartAt']}", result=inp))
    elif stype == "Map":
        items = list(inp if inp is not None else [])
        exec_n = ctx.get("exec_n", 0)
        ctx["exec_n"] = exec_n + 1
        jctx = ctx.get_trigger_context(f"{wf}/{scope}/{name}/join")
        jctx["expected"] = len(items)  # dynamic width via introspection (§5.2)
        if not items:
            ctx.produce(termination_event(f"done|{scope}|{name}", result=[]))
            return
        sm = _MACHINES[params["sm"]]
        iterator = state["Iterator"]
        for i, item in enumerate(items):
            iscope = f"{scope}/{name}#{exec_n}[{i}]"
            for trg in sm._compile(wf, iterator, iscope):
                ctx.add_trigger(trg)
            # alias the item machine's end to the map join subject
            ctx.add_trigger(make_trigger(
                f"end|{iscope}",
                action={"name": "produce", "subject": f"mapend|{scope}|{name}",
                        "pass_result": True},
                trigger_id=f"{wf}/{iscope}/$alias"))
            ctx.produce(termination_event(
                f"enter|{iscope}|{iterator['StartAt']}", result=item))
    elif stype == "Succeed":
        ctx.produce(termination_event(f"end|{scope}", result=inp))
    elif stype == "Fail":
        ctx.workflow_result({"status": "failed", "error": state.get("Error", "Fail"),
                             "cause": state.get("Cause")})


def _route_next(ctx, event, params) -> None:
    from .events import TYPE_FAILURE

    state = _state_def(params)
    if event.type == TYPE_FAILURE:
        # ASL error handling: Catch → next state, else the execution fails
        err = (event.data or {}).get("error") if isinstance(event.data, dict) else None
        catch = state.get("Catch")
        if catch:
            ctx.produce(termination_event(
                f"enter|{params['scope']}|{catch[0]['Next']}",
                result={"error": err}))
            return
        ctx.workflow_result({"status": "failed", "error": err or "States.TaskFailed",
                             "state": params["state"]})
        return
    _route(ctx, params, state, _result_of(event))


def _join_done(ctx, event, params) -> None:
    results = list(ctx.get("fired_results") or [])
    ctx.produce(termination_event(
        f"done|{params['scope']}|{params['state']}", result=results))


def _choice(ctx, event, params) -> None:
    nxt = ctx.get("matched_next")
    if nxt is None:
        ctx.workflow_result({"status": "failed", "error": "States.NoChoiceMatched"})
        return
    ctx.produce(termination_event(
        f"enter|{params['scope']}|{nxt}", result=_result_of(event)))


register_pyfunc("asl.enter_start", _enter_start)
register_pyfunc("asl.exec_state", _exec_state)
register_pyfunc("asl.route_next", _route_next)
register_pyfunc("asl.join_done", _join_done)
register_pyfunc("asl.choice", _choice)


def register_timer_source(workflow: str, timers) -> None:
    _TIMERS[workflow] = timers
