"""CloudEvents 1.0 subset used by Triggerflow.

The paper (§3.2) matches events to triggers via the ``subject`` field and
describes the event kind via ``type``.  Termination/failure events use
``type`` to notify success (+result) or failure (+error info).  Every event
carries a unique ``id`` used for at-least-once dedup (§3.4).
"""
from __future__ import annotations

import itertools
import json
import os
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

SPECVERSION = "1.0"

# Well-known event types (paper §3.2 / §5).
TYPE_INIT = "event.triggerflow.init"
TYPE_TERMINATION = "event.triggerflow.termination.success"
TYPE_FAILURE = "event.triggerflow.termination.failure"
TYPE_TIMEOUT = "event.triggerflow.timeout"
TYPE_WORKFLOW_END = "event.triggerflow.workflow.end"

_counter = itertools.count()
# Uniqueness must hold across *processes* now that the shard runtime forks
# workers (repro.bus.proc): a forked child inherits the parent's counter
# position, so the prefix carries the pid (plus a random salt against pid
# reuse across restarts) and is re-derived in fork children.
_prefix = f"{os.getpid():x}.{uuid.uuid4().hex[:8]}"


def _reseed_id_prefix() -> None:
    global _prefix
    _prefix = f"{os.getpid():x}.{uuid.uuid4().hex[:8]}"


if hasattr(os, "register_at_fork"):  # not on Windows
    os.register_at_fork(after_in_child=_reseed_id_prefix)


def _new_id() -> str:
    # uuid4-per-event is comparatively expensive; the paper only requires
    # uniqueness, so ids are a per-process prefix + a counter.
    return f"{_prefix}-{next(_counter):x}"


@dataclass(frozen=True)
class CloudEvent:
    """Immutable CloudEvent.  ``subject`` routes to triggers, ``type`` filters."""

    subject: str
    type: str = TYPE_TERMINATION
    data: Any = None
    source: str = "triggerflow"
    id: str = field(default_factory=_new_id)
    time: Optional[float] = None
    specversion: str = SPECVERSION
    # CloudEvents extension attributes (the trace plane's ``tftrace``
    # context lives here — repro.obs.trace).  None for the common untraced
    # event: ``to_dict`` then emits nothing, keeping the bus codec's line
    # format (and its cost) unchanged.
    ext: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "specversion": self.specversion,
            "id": self.id,
            "source": self.source,
            "subject": self.subject,
            "type": self.type,
            "time": self.time,
            "data": self.data,
        }
        if self.ext is not None:
            d["ext"] = self.ext
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), separators=(",", ":"))

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "CloudEvent":
        # Deserialization is the file-bus consumer's per-event floor, so it
        # bypasses the frozen-dataclass __init__ (~4x): build the instance
        # directly in __dict__ (writes don't go through __setattr__).
        ev = object.__new__(CloudEvent)
        ev.__dict__.update({
            "subject": d["subject"],
            "type": d.get("type", TYPE_TERMINATION),
            "data": d.get("data"),
            "source": d.get("source", "triggerflow"),
            "id": d["id"],
            "time": d.get("time"),
            "specversion": d.get("specversion", SPECVERSION),
            "ext": d.get("ext"),
        })
        return ev

    @staticmethod
    def from_json(s: str) -> "CloudEvent":
        return CloudEvent.from_dict(json.loads(s))


def stamp_publish_time(events, now: Optional[float] = None) -> None:
    """Set ``time`` (publish wall clock) on events that lack one — the
    metrics plane's publish→consume lag reads it on the consumer side.
    One ``time()`` call per batch; writes go through ``__dict__`` (frozen
    dataclass, same trick as ``from_dict``)."""
    import time as _time

    t = now if now is not None else _time.time()
    for e in events:
        if e.time is None:
            e.__dict__["time"] = t


def termination_event(subject: str, result: Any = None, **extra: Any) -> CloudEvent:
    data = {"result": result}
    data.update(extra)
    return CloudEvent(subject=subject, type=TYPE_TERMINATION, data=data)


def failure_event(subject: str, error: str, **extra: Any) -> CloudEvent:
    data = {"error": error}
    data.update(extra)
    return CloudEvent(subject=subject, type=TYPE_FAILURE, data=data)
