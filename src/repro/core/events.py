"""CloudEvents 1.0 subset used by Triggerflow.

The paper (§3.2) matches events to triggers via the ``subject`` field and
describes the event kind via ``type``.  Termination/failure events use
``type`` to notify success (+result) or failure (+error info).  Every event
carries a unique ``id`` used for at-least-once dedup (§3.4).
"""
from __future__ import annotations

import itertools
import os
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core import codec as _codec

SPECVERSION = "1.0"

# Well-known event types (paper §3.2 / §5).
TYPE_INIT = "event.triggerflow.init"
TYPE_TERMINATION = "event.triggerflow.termination.success"
TYPE_FAILURE = "event.triggerflow.termination.failure"
TYPE_TIMEOUT = "event.triggerflow.timeout"
TYPE_WORKFLOW_END = "event.triggerflow.workflow.end"

_counter = itertools.count()
# Uniqueness must hold across *processes* now that the shard runtime forks
# workers (repro.bus.proc): a forked child inherits the parent's counter
# position, so the prefix carries the pid (plus a random salt against pid
# reuse across restarts) and is re-derived in fork children.
_prefix = f"{os.getpid():x}.{uuid.uuid4().hex[:8]}"


def _reseed_id_prefix() -> None:
    global _prefix
    _prefix = f"{os.getpid():x}.{uuid.uuid4().hex[:8]}"


if hasattr(os, "register_at_fork"):  # not on Windows
    os.register_at_fork(after_in_child=_reseed_id_prefix)


def _new_id() -> str:
    # uuid4-per-event is comparatively expensive; the paper only requires
    # uniqueness, so ids are a per-process prefix + a counter.
    return f"{_prefix}-{next(_counter):x}"


@dataclass(frozen=True)
class CloudEvent:
    """Immutable CloudEvent.  ``subject`` routes to triggers, ``type`` filters."""

    subject: str
    type: str = TYPE_TERMINATION
    data: Any = None
    source: str = "triggerflow"
    id: str = field(default_factory=_new_id)
    time: Optional[float] = None
    specversion: str = SPECVERSION
    # CloudEvents extension attributes (the trace plane's ``tftrace``
    # context lives here — repro.obs.trace).  None for the common untraced
    # event: ``to_dict`` then emits nothing, keeping the bus codec's line
    # format (and its cost) unchanged.
    ext: Optional[Dict[str, Any]] = None

    # The (de)serialization implementations live in repro.core.codec —
    # the single encode and single decode shared by every surface
    # (per-event JSON, batch lines, columnar frames).  Bound below after
    # _codec._install so the hot paths pay no extra call indirection.


# codec needs the class (and its field defaults) to materialize events;
# binding the methods here keeps exactly one implementation of each.
_codec._install(CloudEvent)
CloudEvent.to_dict = _codec.event_to_dict
CloudEvent.to_json = _codec.event_to_json
CloudEvent.from_dict = staticmethod(_codec.event_from_dict)
CloudEvent.from_json = staticmethod(_codec.event_from_json)


def stamp_publish_time(events, now: Optional[float] = None) -> None:
    """Set ``time`` (publish wall clock) on events that lack one — the
    metrics plane's publish→consume lag reads it on the consumer side.
    One ``time()`` call per batch; writes go through ``__dict__`` (frozen
    dataclass, same trick as ``from_dict``)."""
    import time as _time

    t = now if now is not None else _time.time()
    for e in events:
        if e.time is None:
            e.__dict__["time"] = t


def termination_event(subject: str, result: Any = None, **extra: Any) -> CloudEvent:
    data = {"result": result}
    data.update(extra)
    return CloudEvent(subject=subject, type=TYPE_TERMINATION, data=data)


def failure_event(subject: str, error: str, **extra: Any) -> CloudEvent:
    data = {"error": error}
    data.update(extra)
    return CloudEvent(subject=subject, type=TYPE_FAILURE, data=data)
