"""Per-trigger fault-tolerant Context with computational reflection (§3.2).

The Context is a key-value structure holding trigger state (join counters,
aggregated results, ...).  It also exposes the *introspection/interception*
surface the paper describes:

* read/modify the context of *other* triggers (e.g. a Map action sets the
  expected join count on the downstream aggregation trigger, §5.1/§5.2),
* dynamically add/enable/disable triggers (§5.3 dynamic triggers),
* produce events into the worker's internal event sink so that condition/
  action code can fire downstream triggers (§5.2 sub-state-machine
  termination events),
* access the committed event log for event-sourcing replay (§5.3).

Checkpoint cost is proportional to *change*, not state: mutations are
tracked per key, and ``take_delta`` emits either a full ``replace`` snapshot
(first checkpoint of this context object, or after a bulk mutation) or an
incremental ``{"set": ..., "del": ...}`` record the state store applies as a
log entry (see ``StateStore.put_contexts_delta``).

Persistence contract for condition/action authors: mutate context state via
key **assignment** (``ctx[k] = v`` — the built-in aggregators reassign even
when the object is unchanged, e.g. ``ctx["results"] = results``).  In-place
mutation of a nested value without reassigning its key is invisible to the
dirty tracking and will not be checkpointed (it never reliably was: the old
full-snapshot path only captured such changes as a side effect of *another*
key being dirty).  ``ctx.dirty = True`` forces a full ``replace`` snapshot
at the next checkpoint as an explicit escape hatch.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, List

from .events import CloudEvent

if TYPE_CHECKING:  # pragma: no cover
    from .worker import TFWorker

_MISSING = object()


def jsonable(value: Any) -> Any:
    """JSON-safe view of a context value.  In-memory contexts may hold sets
    (the ``exactly_once`` dedup index); checkpoints serialize them as sorted
    lists so the JSON stores and crash-recovery replay stay deterministic."""
    if isinstance(value, (set, frozenset)):
        return sorted(value)
    return value


class TriggerContext(dict):
    """dict subclass: the JSON-serializable payload *is* the dict content."""

    def __init__(self, data: Dict[str, Any], worker: "TFWorker", trigger_id: str):
        super().__init__(data)
        self._worker = worker
        self.trigger_id = trigger_id
        self.workflow = worker.workflow
        # Delta tracking: which keys changed since the last checkpoint.  The
        # first checkpoint of a fresh context object always emits a full
        # ``replace`` so the store's view never depends on pre-crash deltas.
        self._dirty_keys: set = set()
        self._deleted_keys: set = set()
        self._full_dirty = False
        self._replace_next = True

    # -- mutation tracking (what the checkpoint persists) ---------------------
    @property
    def dirty(self) -> bool:
        return self._full_dirty or bool(self._dirty_keys) or bool(self._deleted_keys)

    @dirty.setter
    def dirty(self, value: bool) -> None:
        if value:
            self._full_dirty = True
        else:
            self._full_dirty = False
            self._dirty_keys.clear()
            self._deleted_keys.clear()

    def __setitem__(self, k, v) -> None:
        self._dirty_keys.add(k)
        self._deleted_keys.discard(k)
        super().__setitem__(k, v)

    def __delitem__(self, k) -> None:
        super().__delitem__(k)
        self._dirty_keys.discard(k)
        self._deleted_keys.add(k)

    def update(self, *a, **kw) -> None:  # type: ignore[override]
        super().update(*a, **kw)
        if a and not isinstance(a[0], dict):
            self._full_dirty = True  # iterable-of-pairs: don't re-walk it
        else:
            keys = set(a[0]) if a else set()
            keys.update(kw)
            self._dirty_keys.update(keys)
            self._deleted_keys.difference_update(keys)

    def setdefault(self, k, default=None):  # type: ignore[override]
        if k not in self:
            self._dirty_keys.add(k)
            self._deleted_keys.discard(k)
        return super().setdefault(k, default)

    def pop(self, k, *a):  # type: ignore[override]
        if k in self:
            self._dirty_keys.discard(k)
            self._deleted_keys.add(k)
        return super().pop(k, *a)

    def clear(self) -> None:  # type: ignore[override]
        self._full_dirty = True
        super().clear()

    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe copy of the full context (full-rewrite checkpoints)."""
        return {k: jsonable(v) for k, v in self.items()}

    def build_delta(self) -> Dict[str, Any]:
        """The pending mutations as a checkpoint delta record (pure read).

        Returns ``{"replace": {...}}`` (authoritative full snapshot) or
        ``{"set": {...}, "del": [...]}``.  Call ``mark_checkpointed`` only
        after the store acknowledged the write — a failed write must leave
        the dirty tracking intact so the delta is re-emitted."""
        if self._replace_next or self._full_dirty:
            return {"replace": self.snapshot()}
        delta: Dict[str, Any] = {}
        changed = {k: jsonable(self[k]) for k in self._dirty_keys if k in self}
        deleted = sorted(k for k in self._deleted_keys if k not in self)
        if changed:
            delta["set"] = changed
        if deleted:
            delta["del"] = deleted
        return delta

    def mark_checkpointed(self) -> None:
        """Reset dirty tracking after the delta was durably persisted."""
        self._replace_next = False
        self._full_dirty = False
        self._dirty_keys.clear()
        self._deleted_keys.clear()

    def take_delta(self) -> Dict[str, Any]:
        """``build_delta`` + ``mark_checkpointed`` in one step (callers that
        persist synchronously and cannot fail in between)."""
        delta = self.build_delta()
        self.mark_checkpointed()
        return delta

    # -- introspection / reflection (paper Def. 5) ----------------------------
    def get_trigger_context(self, trigger_id: str) -> "TriggerContext":
        return self._worker.context_of(trigger_id)

    def add_trigger(self, trigger) -> str:
        """Dynamically register a trigger from inside condition/action code."""
        return self._worker.add_dynamic_trigger(trigger)

    def enable_trigger(self, trigger_id: str) -> None:
        self._worker.set_trigger_enabled(trigger_id, True)

    def disable_trigger(self, trigger_id: str) -> None:
        self._worker.set_trigger_enabled(trigger_id, False)

    def intercept_trigger(self, trigger_id: str, action_spec: Dict[str, Any]) -> None:
        self._worker.intercept(trigger_id, action_spec)

    # -- event production ------------------------------------------------------
    def produce(self, event: CloudEvent) -> None:
        """Emit into the worker's internal sink (processed later this batch)."""
        self._worker.sink(event)

    def produce_batch(self, events: List[CloudEvent]) -> None:
        """Bulk ``produce``: one store append per partition and one commit-log
        write for the whole run (the batched-action fan-out path)."""
        self._worker.sink_batch(list(events))

    def invoke(self, fn_name: str, args: Any, subject: str, **kw) -> None:
        """Asynchronously invoke a registered 'serverless function' (§3.2 Action)."""
        self._worker.backend.invoke(self.workflow, fn_name, args, subject, **kw)

    def timeout(self, subject: str, delay: float, data: Any = None) -> None:
        """Schedule a timeout event via the timer event source (§5.4)."""
        from .events import TYPE_TIMEOUT

        self._worker.timers.after(
            self.workflow, delay, CloudEvent(subject=subject, type=TYPE_TIMEOUT, data=data))

    # -- event sourcing --------------------------------------------------------
    def committed_events(self) -> List[CloudEvent]:
        return self._worker.event_store.committed_events(self.workflow)

    def local_events(self) -> List[CloudEvent]:
        """Events retained in worker memory (native-scheduler fast replay, §6.3.2)."""
        return self._worker.event_log

    def workflow_result(self, value: Any) -> None:
        self._worker.set_result(value)


def apply_context_delta(current: Dict[str, Any], delta: Dict[str, Any]) -> Dict[str, Any]:
    """Apply one ``take_delta`` record to a stored context dict."""
    if "replace" in delta:
        return dict(delta["replace"])
    out = dict(current)
    out.update(delta.get("set", {}))
    for k in delta.get("del", ()):
        out.pop(k, None)
    return out
