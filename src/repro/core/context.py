"""Per-trigger fault-tolerant Context with computational reflection (§3.2).

The Context is a key-value structure holding trigger state (join counters,
aggregated results, ...).  It also exposes the *introspection/interception*
surface the paper describes:

* read/modify the context of *other* triggers (e.g. a Map action sets the
  expected join count on the downstream aggregation trigger, §5.1/§5.2),
* dynamically add/enable/disable triggers (§5.3 dynamic triggers),
* produce events into the worker's internal event sink so that condition/
  action code can fire downstream triggers (§5.2 sub-state-machine
  termination events),
* access the committed event log for event-sourcing replay (§5.3).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, List, Optional

from .events import CloudEvent

if TYPE_CHECKING:  # pragma: no cover
    from .worker import TFWorker


class TriggerContext(dict):
    """dict subclass: the JSON-serializable payload *is* the dict content."""

    def __init__(self, data: Dict[str, Any], worker: "TFWorker", trigger_id: str):
        super().__init__(data)
        self._worker = worker
        self.trigger_id = trigger_id
        self.workflow = worker.workflow
        self.dirty = False

    # -- mutation tracking (what the checkpoint persists) ---------------------
    def __setitem__(self, k, v) -> None:
        self.dirty = True
        super().__setitem__(k, v)

    def update(self, *a, **kw) -> None:  # type: ignore[override]
        self.dirty = True
        super().update(*a, **kw)

    def setdefault(self, k, default=None):  # type: ignore[override]
        if k not in self:
            self.dirty = True
        return super().setdefault(k, default)

    def pop(self, *a):  # type: ignore[override]
        self.dirty = True
        return super().pop(*a)

    # -- introspection / reflection (paper Def. 5) ----------------------------
    def get_trigger_context(self, trigger_id: str) -> "TriggerContext":
        return self._worker.context_of(trigger_id)

    def add_trigger(self, trigger) -> str:
        """Dynamically register a trigger from inside condition/action code."""
        return self._worker.add_dynamic_trigger(trigger)

    def enable_trigger(self, trigger_id: str) -> None:
        self._worker.set_trigger_enabled(trigger_id, True)

    def disable_trigger(self, trigger_id: str) -> None:
        self._worker.set_trigger_enabled(trigger_id, False)

    def intercept_trigger(self, trigger_id: str, action_spec: Dict[str, Any]) -> None:
        self._worker.intercept(trigger_id, action_spec)

    # -- event production ------------------------------------------------------
    def produce(self, event: CloudEvent) -> None:
        """Emit into the worker's internal sink (processed later this batch)."""
        self._worker.sink(event)

    def invoke(self, fn_name: str, args: Any, subject: str, **kw) -> None:
        """Asynchronously invoke a registered 'serverless function' (§3.2 Action)."""
        self._worker.backend.invoke(self.workflow, fn_name, args, subject, **kw)

    def timeout(self, subject: str, delay: float, data: Any = None) -> None:
        """Schedule a timeout event via the timer event source (§5.4)."""
        from .events import TYPE_TIMEOUT

        self._worker.timers.after(
            self.workflow, delay, CloudEvent(subject=subject, type=TYPE_TIMEOUT, data=data))

    # -- event sourcing --------------------------------------------------------
    def committed_events(self) -> List[CloudEvent]:
        return self._worker.event_store.committed_events(self.workflow)

    def local_events(self) -> List[CloudEvent]:
        """Events retained in worker memory (native-scheduler fast replay, §6.3.2)."""
        return self._worker.event_log

    def workflow_result(self, value: Any) -> None:
        self._worker.set_result(value)
