"""§5.3 — Workflow as Code with event sourcing (Lithops / Durable-Functions
style) on top of dynamic triggers.

The user writes an ordinary imperative *orchestrator function*::

    def my_workflow(ex):
        f = ex.call_async("train", {"steps": 100})
        state = f.result()                     # suspends here until the event
        parts = ex.map("evaluate", shards)     # fan-out
        return combine(parts.result())

Calling ``.result()`` on an unresolved future raises ``Suspend``: the
orchestrator stops (and can be deprovisioned — scale-to-zero while the tasks
run).  Each ``call_async``/``map`` registers a *dynamic trigger* on a
deterministic invocation key; when the termination event(s) arrive, the
trigger fires and **replays** the orchestrator from the start.  Replay is pure
event sourcing: previously-invoked calls resolve instantly from recorded
results, so execution continues from the last suspension point.  User code is
unchanged between local and Triggerflow execution (paper: Lithops portability).

Two schedulers, as in the paper:
* ``native``   — replay inside the TF-Worker action; results are resolved
                 from the wake triggers' in-memory contexts (fast path).
* ``external`` — simulates Lithops/ADF: the orchestrator runs as a backend
                 "cloud function"; every replay re-reads the event store
                 (``committed_events`` + wake contexts), counting store
                 round-trips — the quantity Fig. 11 measures.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

from .actions import register_pyfunc
from .service import Triggerflow
from .triggers import make_trigger

_ORCHESTRATORS: Dict[str, "WorkflowAsCode"] = {}


class Suspend(Exception):
    """Raised when awaiting a future whose termination event hasn't arrived."""


class TFFuture:
    __slots__ = ("key", "_executor", "n")

    def __init__(self, key: str, executor: "CodeExecutor", n: int = 1):
        self.key = key
        self._executor = executor
        self.n = n

    def done(self) -> bool:
        return self.key in self._executor.resolved

    def result(self) -> Any:
        if not self.done():
            raise Suspend(self.key)
        return self._executor.resolved[self.key]


class CodeExecutor:
    """Per-replay execution context handed to the orchestrator function."""

    def __init__(self, wac: "WorkflowAsCode", ctx, resolved: Dict[str, Any]):
        self._wac = wac
        self._ctx = ctx  # ctrl trigger context (persists `invoked`)
        self.resolved = resolved
        self._seq = 0
        self.store_requests = 0  # external-scheduler accounting (Fig. 11)

    def _next_key(self, kind: str) -> str:
        key = f"wac|{kind}{self._seq}"
        self._seq += 1
        return key

    # -- the Lithops-like API -------------------------------------------------
    def call_async(self, fn_name: str, args: Any = None) -> TFFuture:
        key = self._next_key("c")
        self._ensure_invoked(key, fn_name, [args], 1)
        return TFFuture(key, self, 1)

    def map(self, fn_name: str, items) -> TFFuture:
        items = list(items)
        key = self._next_key("m")
        self._ensure_invoked(key, fn_name, items, len(items))
        return TFFuture(key, self, len(items))

    def _ensure_invoked(self, key: str, fn_name: str, args_list: List[Any], n: int) -> None:
        invoked = self._ctx.get("invoked") or {}
        if key in invoked:
            return
        # dynamic trigger: termination event(s) on `key` wake the orchestrator
        self._ctx.add_trigger(make_trigger(
            key,
            condition={"name": "counter", "expected": max(n, 1)},
            action={"name": "pyfunc", "func": "wac.wake", "wac": self._wac.wac_id,
                    "key": key},
            trigger_id=f"{self._wac.workflow}/{key}",
        ))
        for a in args_list:
            self._ctx.invoke(fn_name, a, key)
        invoked[key] = n
        self._ctx["invoked"] = invoked


class WorkflowAsCode:
    def __init__(self, tf: Triggerflow, workflow: str,
                 orchestrator: Callable[[CodeExecutor], Any],
                 scheduler: str = "native"):
        assert scheduler in ("native", "external")
        self.tf = tf
        self.workflow = workflow
        self.orchestrator = orchestrator
        self.scheduler = scheduler
        self.wac_id = workflow
        self.replays = 0
        self.store_requests = 0
        _ORCHESTRATORS[self.wac_id] = self

    def deploy(self) -> None:
        self.tf.create_workflow(self.workflow, {"kind": "workflow_as_code",
                                                "scheduler": self.scheduler})
        self.tf.add_trigger(self.workflow, make_trigger(
            "$init",
            action={"name": "pyfunc", "func": "wac.wake", "wac": self.wac_id,
                    "key": "$init"},
            trigger_id=f"{self.workflow}/$ctrl",
            transient=False,
        ))

    def run(self, timeout: float = 60.0) -> Any:
        self.tf.init_workflow(self.workflow)
        return self.tf.run_until_complete(self.workflow, timeout=timeout)

    # -- replay ------------------------------------------------------------------
    def _resolve_results(self, ctx) -> Dict[str, Any]:
        """Event sourcing: reconstruct {invocation key -> result(s)}."""
        invoked = ctx.get("invoked") or {}
        resolved: Dict[str, Any] = {}
        if self.scheduler == "external":
            # cloud-function replay: one store read per step (the n-requests
            # behaviour Fig. 11 quantifies), from durable committed events +
            # checkpointed trigger contexts
            self.store_requests += 1
            events = ctx.committed_events() + ctx.local_events()
            by_key: Dict[str, List[Any]] = {}
            for ev in events:
                if ev.subject in invoked and isinstance(ev.data, dict) and "result" in ev.data:
                    by_key.setdefault(ev.subject, []).append(ev.data["result"])
            for key, n in invoked.items():
                vals = by_key.get(key, [])
                if len(vals) >= n:
                    resolved[key] = vals[0] if key.startswith("wac|c") else vals[:n]
        else:
            # native scheduler: wake-trigger contexts hold aggregated results
            for key, n in invoked.items():
                tid = f"{self.workflow}/{key}"
                try:
                    tctx = ctx.get_trigger_context(tid)
                except KeyError:
                    continue
                vals = tctx.get("fired_results") or tctx.get("results") or []
                if len(vals) >= n:
                    resolved[key] = vals[0] if key.startswith("wac|c") else list(vals[:n])
        return resolved

    def replay(self, ctx) -> None:
        self.replays += 1
        resolved = self._resolve_results(ctx)
        ex = CodeExecutor(self, ctx, resolved)
        try:
            out = self.orchestrator(ex)
        except Suspend:
            return  # parked until the next termination event wakes us
        ctx.workflow_result({"status": "succeeded", "result": out,
                             "replays": self.replays})


def _wake(ctx, event, params) -> None:
    wac = _ORCHESTRATORS[params["wac"]]
    # ctrl context lives on the $ctrl trigger; wake triggers delegate to it
    ctrl_ctx = ctx if params["key"] == "$init" else ctx.get_trigger_context(
        f"{wac.workflow}/$ctrl")
    if wac.scheduler == "external":
        # run in a backend thread like a re-invoked cloud function
        wac.replay(ctrl_ctx)
    else:
        wac.replay(ctrl_ctx)


register_pyfunc("wac.wake", _wake)
