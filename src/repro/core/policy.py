"""Failure-policy plane: retry policies, poison quarantine, circuit breakers.

The paper claims Triggerflow "transparently guarantees fault tolerance" for
long-running workflows; PRs 1-6 built the crash/replay half of that claim
(SIGKILL recovery, torn-tail repair, exactly-once commits).  This module is
the *policy* half — what to do when the failure is not the process but the
work itself:

* ``RetryPolicy`` — a per-trigger budget for failed condition/action runs:
  max attempts, exponential backoff with deterministic jitter, and an
  optional per-attempt wall-clock timeout enforced by a watchdog thread.
  Attempt counts live in the trigger's durable context (they ride the
  checkpoint-before-commit path, so they survive SIGKILL and never reset on
  replay).  After budget exhaustion the event is quarantined to the DLQ with
  a structured reason instead of hot-looping the shard.

* DLQ reason taxonomy — quarantined events carry ``ext["tfdlq"]`` metadata
  (reason, attempts, first/last failure timestamps).  ``redrive(reasons=…)``
  filters on it so re-enabling a trigger redrives only ``disabled``
  quarantines and never re-injects poison.

* ``CircuitBreaker`` — per-workflow consecutive-crash-streak tracking for
  the pool runtimes and the autoscaler: restarts back off exponentially
  (first crash restarts free so deliberate ``crash_shard`` recovery stays
  immediate), past a threshold the workflow is circuit-broken (no restarts)
  until a cooldown elapses, then a single half-open probe shard decides
  whether to close the circuit or re-open it.

Everything here is deterministic: backoff jitter is keyed off
``crc32(event_id:attempt)`` — two replays of the same failed event compute
the same schedule, which is what makes the chaos soak replayable.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Any, Dict, Iterable, Optional

from .events import CloudEvent

# Reserved context key holding {event_id: [attempts, first_ts, last_ts]} for
# in-flight retries.  It rides put_contexts_delta like any user key, so the
# counter is durable (exactly-once retries across SIGKILL).
RETRY_STATE_KEY = "__attempts__"

# DLQ reason taxonomy.  ``disabled`` is the pre-existing quarantine class
# (event arrived while every matching trigger was disabled) and the default
# for legacy entries without metadata; the ``poison:*`` classes are terminal
# retry-budget exhaustions and are never auto-redriven.
DLQ_META_KEY = "tfdlq"
REASON_DISABLED = "disabled"
REASON_ACTION_ERROR = "poison:action-error"
REASON_TIMEOUT = "poison:timeout"
REASON_CONDITION_ERROR = "poison:condition-error"

# What the worker/pools redrive automatically (on fire progress or trigger
# re-enable).  Poison stays put until an operator redrives explicitly.
AUTO_REDRIVE_REASONS = (REASON_DISABLED,)


class ActionTimeout(Exception):
    """An action exceeded its RetryPolicy.action_timeout budget."""


class RetryPolicy:
    """Per-trigger retry budget with deterministic exponential backoff.

    ``max_attempts`` counts total runs (1 = no retry, fail straight to the
    DLQ).  Backoff for attempt *n* (1-based) is
    ``min(backoff_max, backoff_base * backoff_factor**(n-1))`` stretched by
    up to ``jitter`` fraction, keyed off ``crc32(event_id:n)`` so the same
    failed event always computes the same schedule (replayable chaos runs).
    ``action_timeout`` (seconds), when set, runs each action attempt under a
    watchdog thread; overruns count as failures of class ``timeout``.
    """

    __slots__ = ("max_attempts", "backoff_base", "backoff_factor",
                 "backoff_max", "jitter", "action_timeout")

    def __init__(self, max_attempts: int = 3, backoff_base: float = 0.05,
                 backoff_factor: float = 2.0, backoff_max: float = 5.0,
                 jitter: float = 0.1,
                 action_timeout: Optional[float] = None):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.max_attempts = int(max_attempts)
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.backoff_max = float(backoff_max)
        self.jitter = float(jitter)
        self.action_timeout = action_timeout

    def backoff(self, attempt: int, event_id: str) -> float:
        """Delay before retrying after failed attempt ``attempt`` (1-based)."""
        base = min(self.backoff_max,
                   self.backoff_base * self.backoff_factor ** max(0, attempt - 1))
        if self.jitter <= 0.0:
            return base
        u = zlib.crc32(f"{event_id}:{attempt}".encode()) / 2 ** 32
        return base * (1.0 + self.jitter * u)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "max_attempts": self.max_attempts,
            "backoff_base": self.backoff_base,
            "backoff_factor": self.backoff_factor,
            "backoff_max": self.backoff_max,
            "jitter": self.jitter,
        }
        if self.action_timeout is not None:
            d["action_timeout"] = self.action_timeout
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RetryPolicy":
        return cls(max_attempts=d.get("max_attempts", 3),
                   backoff_base=d.get("backoff_base", 0.05),
                   backoff_factor=d.get("backoff_factor", 2.0),
                   backoff_max=d.get("backoff_max", 5.0),
                   jitter=d.get("jitter", 0.1),
                   action_timeout=d.get("action_timeout"))

    def __repr__(self) -> str:  # debugging / TimeoutError diagnostics
        return (f"RetryPolicy(max_attempts={self.max_attempts}, "
                f"backoff_base={self.backoff_base}, "
                f"action_timeout={self.action_timeout})")


def coerce_retry_policy(retry: Any) -> Optional[Dict[str, Any]]:
    """Normalise a user-supplied retry spec to its dict form (or None)."""
    if retry is None:
        return None
    if isinstance(retry, RetryPolicy):
        return retry.to_dict()
    if isinstance(retry, dict):
        return RetryPolicy.from_dict(retry).to_dict()  # validate
    raise TypeError(f"retry must be RetryPolicy or dict, got {type(retry)!r}")


# -- DLQ metadata ----------------------------------------------------------------

def quarantined(event: CloudEvent, reason: str, attempts: int = 0,
                first_failure: Optional[float] = None,
                last_failure: Optional[float] = None) -> CloudEvent:
    """A copy of ``event`` tagged with structured DLQ metadata in ``ext``.

    The copy (same id) replaces the live event in the DLQ; the metadata rides
    the event's JSON form through every store family (memory deques, .dlq
    segments, partitioned ledgers) and through redrive back into the stream.
    """
    meta: Dict[str, Any] = {"reason": reason}
    if attempts:
        meta["attempts"] = attempts
    if first_failure is not None:
        meta["first_failure"] = first_failure
    if last_failure is not None:
        meta["last_failure"] = last_failure
    tagged = CloudEvent.__new__(CloudEvent)
    d = dict(event.__dict__)
    d["ext"] = dict(event.ext or {})
    d["ext"][DLQ_META_KEY] = meta
    tagged.__dict__.update(d)  # frozen dataclass: bypass __init__, same as from_dict
    return tagged


def dlq_meta(event: CloudEvent) -> Dict[str, Any]:
    ext = getattr(event, "ext", None)
    if ext:
        meta = ext.get(DLQ_META_KEY)
        if isinstance(meta, dict):
            return meta
    return {}


def dlq_reason(event: CloudEvent) -> str:
    """Quarantine reason; legacy entries without metadata are ``disabled``."""
    return dlq_meta(event).get("reason", REASON_DISABLED)


def reason_matches(event: CloudEvent, reasons: Optional[Iterable[str]]) -> bool:
    return reasons is None or dlq_reason(event) in reasons


def reason_counter_name(reason: str) -> str:
    """Sanitised per-reason Prometheus counter name.

    The renderer emits plain ``name value`` lines (no label support), so the
    reason is folded into the metric name: ``poison:action-error`` →
    ``tf_poison_action_error_total``; ``disabled`` →
    ``tf_quarantined_disabled_total``.
    """
    slug = reason.replace("poison:", "poison_").replace("-", "_").replace(":", "_")
    if not slug.startswith("poison_"):
        return f"tf_quarantined_{slug}_total"
    return f"tf_{slug}_total"


# -- action watchdog -------------------------------------------------------------

def call_with_timeout(timeout: Optional[float], fn, *args):
    """Run ``fn(*args)`` with a wall-clock budget.

    Without a timeout this is a direct call (zero overhead for policies that
    only set a retry budget).  With one, the call runs on a daemon watchdog
    thread and an overrun raises ActionTimeout in the caller.  The overrun
    thread itself cannot be killed (CPython) — it is abandoned; actions run
    under a timeout should therefore be side-effect-idempotent, the same
    contract redelivery already imposes.
    """
    if timeout is None:
        return fn(*args)
    box: list = []
    done = threading.Event()

    def _run() -> None:
        try:
            box.append((True, fn(*args)))
        except BaseException as exc:  # noqa: BLE001 - re-raised in caller
            box.append((False, exc))
        finally:
            done.set()

    t = threading.Thread(target=_run, daemon=True, name="tf-watchdog")
    t.start()
    if not done.wait(timeout):
        raise ActionTimeout(f"action exceeded {timeout}s budget")
    ok, val = box[0]
    if ok:
        return val
    raise val


# -- crash-loop breaker ----------------------------------------------------------

class CircuitBreaker:
    """Consecutive-crash-streak breaker for one workflow's shard fleet.

    States:

    * ``closed`` — restarts allowed; from the *second* consecutive crash on,
      each restart waits out an exponential backoff (the first crash restarts
      free so deliberate ``crash_shard`` recovery is immediate).
    * ``open`` — streak reached ``threshold``: no restarts until ``cooldown``
      elapses, then the breaker goes half-open.
    * ``half_open`` — exactly one probe shard is allowed; a clean exit closes
      the circuit, another crash re-opens it (cooldown restarts).

    Thread-safe; pools call it under their own locks anyway but the
    autoscaler thread reads snapshots concurrently.
    """

    __slots__ = ("threshold", "backoff_base", "backoff_factor", "backoff_max",
                 "cooldown", "clock", "state", "streak", "opened_total",
                 "_last_crash", "_opened_at", "_lock")

    def __init__(self, threshold: int = 5, backoff_base: float = 0.2,
                 backoff_factor: float = 2.0, backoff_max: float = 5.0,
                 cooldown: float = 1.0, clock=time.monotonic):
        self.threshold = int(threshold)
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.backoff_max = float(backoff_max)
        self.cooldown = float(cooldown)
        self.clock = clock
        self.state = "closed"
        self.streak = 0
        self.opened_total = 0  # transitions into "open" (tf_circuit_open_total)
        self._last_crash = 0.0
        self._opened_at = 0.0
        self._lock = threading.Lock()

    # -- event feed (pools call these from reap/exit paths) ----------------------
    def record_crash(self, n: int = 1) -> None:
        with self._lock:
            self.streak += n
            self._last_crash = self.clock()
            if self.state == "half_open" or (
                    self.state == "closed" and self.streak >= self.threshold):
                self.state = "open"
                self._opened_at = self.clock()
                self.opened_total += 1

    def record_clean(self) -> None:
        """A shard retired cleanly (idle/finished/stopped): reset the streak."""
        with self._lock:
            self.streak = 0
            if self.state != "closed":
                self.state = "closed"

    # -- gate --------------------------------------------------------------------
    def restart_backoff(self) -> float:
        """Current restart delay (seconds); 0 while the streak is free."""
        if self.streak < 2:
            return 0.0
        return min(self.backoff_max,
                   self.backoff_base * self.backoff_factor ** (self.streak - 2))

    def allow_start(self, want: int) -> int:
        """How many NEW shard starts are permitted right now (0..want)."""
        if want <= 0:
            return 0
        with self._lock:
            now = self.clock()
            if self.state == "open":
                if now - self._opened_at < self.cooldown:
                    return 0
                self.state = "half_open"
                return 1
            if self.state == "half_open":
                return 1
            delay = self.restart_backoff()
            if delay > 0.0 and now - self._last_crash < delay:
                return 0
            return want

    # -- introspection -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        return {"state": self.state, "streak": self.streak,
                "opened_total": self.opened_total,
                "restart_backoff_seconds": self.restart_backoff()}

    def __repr__(self) -> str:
        return (f"CircuitBreaker(state={self.state!r}, streak={self.streak}, "
                f"opened={self.opened_total})")
