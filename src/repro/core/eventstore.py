"""At-least-once event stores + Dead Letter Queue (paper §3.4, §4.2).

The contract every store implements (mirroring Kafka/Redis-Streams usage in
the paper):

* ``publish`` appends events to a per-workflow stream.
* ``consume`` returns *uncommitted* events in arrival order.  Events may be
  re-delivered after a crash/restart (at-least-once) — consumers must dedup
  by event id and tolerate reordering.
* ``commit`` marks events processed; committed events are never re-delivered.
* A per-workflow DLQ holds events whose trigger is currently disabled
  (out-of-order sequences, §3.4); they are re-enqueued on ``redrive``.

Two backends: in-memory (fast path, Table 1 load tests) and a durable
append-only JSONL file store (crash/restart fault tolerance, Fig 13).
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: single-process only
    fcntl = None  # type: ignore[assignment]

from . import codec
from .events import CloudEvent, stamp_publish_time


class StreamShard:
    """One totally-ordered stream: the commit/DLQ primitive.

    This is the unit both ``MemoryEventStore`` (one shard per workflow) and
    ``repro.bus.PartitionedEventStore`` (one shard per workflow *partition*)
    are built from.  Not thread-safe on its own — the owning store serializes
    access.

    * the pending log — an append-only list with a consume ``head`` offset
      (compacted periodically); ``consume`` peeks without removing
      (at-least-once: events stay until committed).
    * ``commit`` — removes events and records them in commit order.  The
      common case — a worker committing exactly the batch it consumed — is a
      single C-level slice/set comparison + bulk set/list update (O(batch)
      with no per-event interpreter work); ids committed out of arrival order
      (events skipped into the DLQ mid-batch, grouped batch-plane commits
      interleaved with sink events) fall back to a per-event prefix walk and
      finally an O(pending) scan.
    * ``dlq`` — quarantine for events whose trigger is disabled (§3.4);
      ``redrive`` re-appends them to the stream.
    * ``lock`` — carried but never taken here: the owning store decides the
      locking granularity (``MemoryEventStore`` serializes whole-store,
      ``PartitionedEventStore`` stripes on exactly this per-shard lock so
      independent partitions never contend).
    """

    __slots__ = ("_log", "head", "pending_ids", "committed_ids",
                 "_committed_log", "dlq", "_has_dups", "lock")

    #: Compact the consumed prefix of the log once it exceeds this length.
    COMPACT_AT = 8192

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self._log: List[CloudEvent] = []
        self.head = 0  # index of the first uncommitted event in _log
        self.pending_ids: set = set()
        self.committed_ids: set = set()
        self._committed_log: List[CloudEvent] = []  # commit order
        self.dlq: deque = deque()
        # True while the log may hold two copies of one id (a broker-style
        # redelivery via re-publish).  Only then do consume/commit pay the
        # dedup-filtering slow path.
        self._has_dups = False

    def _compact(self) -> None:
        if self.head >= self.COMPACT_AT:
            del self._log[:self.head]
            self.head = 0

    def publish(self, events: Iterable[CloudEvent]) -> None:
        events = list(events)
        if self.dlq:
            # Quarantine is sticky by id: a re-published copy of a DLQ'd
            # event (e.g. a replayed producer re-emitting a poison child)
            # never re-enters the stream — only redrive() can.  Mirrors the
            # durable store's replay filter, which skips dlq_ids.
            dlq_ids = {e.id for e in self.dlq}
            events = [e for e in events if e.id not in dlq_ids]
            if not events:
                return
        self._log.extend(events)
        ids = [e.id for e in events]
        pids = self.pending_ids
        before = len(pids)
        pids.update(ids)
        # C-level dup detection: re-published pending ids, duplicates within
        # the batch, or a copy of an already-committed id.
        if len(pids) - before != len(ids) or not self.committed_ids.isdisjoint(ids):
            self._has_dups = True

    def consume(self, max_events: int) -> List[CloudEvent]:
        batch = self._log[self.head:self.head + max_events]
        if self._has_dups and batch:
            committed = self.committed_ids
            batch = [e for e in batch if e.id not in committed]
        return batch

    def commit_prefix(self, event_ids: set) -> int:
        """Commit the in-order head of the stream that is in ``event_ids``.
        O(committed) — the common case, since consumers process in order.
        Duplicate copies of an already-committed id are consumed from the log
        but committed (logged/counted) only once."""
        log = self._log
        head = self.head
        end = len(log)
        cids = self.committed_ids
        clog = self._committed_log
        n = 0
        while head < end:
            e = log[head]
            eid = e.id
            if eid not in event_ids:
                break
            if eid not in cids:
                cids.add(eid)
                clog.append(e)
                n += 1
            head += 1
        if head != self.head:
            self.pending_ids.difference_update(
                e.id for e in log[self.head:head])
            self.head = head
            self._compact()
        return n

    def commit_scan(self, event_ids: set) -> int:
        """Commit out-of-order ids (events skipped mid-stream, e.g. after a
        DLQ quarantine).  O(pending) — the rare fallback."""
        leftover = event_ids & self.pending_ids
        if not leftover:
            return 0
        n = 0
        keep: List[CloudEvent] = []
        cids = self.committed_ids
        clog = self._committed_log
        for e in self._log[self.head:]:
            if e.id in leftover:
                # duplicate copies are dropped but committed only once
                if e.id not in cids:
                    cids.add(e.id)
                    clog.append(e)
                    n += 1
            else:
                keep.append(e)
        self.pending_ids.difference_update(leftover)
        self._log = keep
        self.head = 0
        return n

    def commit(self, event_ids) -> int:
        """Commit the given ids (ids not pending in this shard are ignored).
        Returns the number of events actually committed here."""
        ids = event_ids if isinstance(event_ids, set) else set(event_ids)
        k = len(ids)
        head = self.head
        log = self._log
        # Bulk fast path: the batch is exactly the next k pending events (in
        # any order).  One slice + two C-level set ops + list extend: no
        # per-event interpreter work at all.
        if k and not self._has_dups and head + k <= len(log):
            batch = log[head:head + k]
            if {e.id for e in batch} == ids:
                self.committed_ids.update(ids)
                self._committed_log.extend(batch)
                self.pending_ids.difference_update(ids)
                self.head = head + k
                self._compact()
                return k
        n = self.commit_prefix(ids)
        if n < k:
            n += self.commit_scan(ids)
        if self._has_dups:
            # Purge surviving copies of committed ids so UNCOMMITTED_ONLY
            # consumers are never handed a committed event again.
            committed = self.committed_ids
            tail = [e for e in self._log[self.head:] if e.id not in committed]
            self._log = tail
            self.head = 0
            self.pending_ids = {e.id for e in tail}
            self._has_dups = len(self.pending_ids) != len(tail)
        return n

    def is_committed(self, event_id: str) -> bool:
        return event_id in self.committed_ids

    def lag(self) -> int:
        return len(self._log) - self.head

    def commit_offset(self) -> int:
        """Monotone per-shard commit offset (Kafka-consumer-group analogue)."""
        return len(self._committed_log)

    def to_dlq(self, event: CloudEvent) -> None:
        # Idempotent by id: a batch holding two copies of one poison event
        # quarantines it once (same dedup discipline commit applies).
        if not any(e.id == event.id for e in self.dlq):
            self.dlq.append(event)
        if event.id in self.pending_ids:
            self.pending_ids.discard(event.id)
            self._log = [e for e in self._log[self.head:] if e.id != event.id]
            self.head = 0

    def redrive(self, reasons=None) -> int:
        """Move DLQ events back into the stream; ``reasons`` (iterable of DLQ
        reason strings) restricts the move — poison quarantines stay put when
        the caller redrives only ``disabled`` entries.  Returns moved count."""
        if not self.dlq:
            return 0
        if reasons is None:
            moved_all = list(self.dlq)
            self.dlq.clear()  # before publish: quarantined ids are filtered
            self.publish(moved_all)
            return len(moved_all)
        from .policy import reason_matches
        moved = [e for e in self.dlq if reason_matches(e, reasons)]
        if moved:
            kept = [e for e in self.dlq if not reason_matches(e, reasons)]
            self.dlq.clear()
            self.dlq.extend(kept)
            self.publish(moved)
        return len(moved)

    def dlq_size(self) -> int:
        return len(self.dlq)

    def dlq_by_reason(self) -> Dict[str, int]:
        from .policy import dlq_reason
        out: Dict[str, int] = {}
        for e in self.dlq:
            r = dlq_reason(e)
            out[r] = out.get(r, 0) + 1
        return out

    def committed_events(self) -> List[CloudEvent]:
        return list(self._committed_log)


def fsync_dir(path: str) -> None:
    """fsync a directory so a freshly-created (or renamed-in) entry survives
    a crash: on journaling filesystems the file's *data* fsync does not imply
    the directory entry reached disk."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - non-POSIX / transient
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class SegmentLog:
    """Append-only record segment: the durable log primitive.

    Two on-disk formats, decided *per file* (never mixed within one):

    * ``v1`` — one text record per line (the original JSONL format).
    * ``tfb1`` — binary: the file starts with ``codec.MAGIC``
      (``TFB1\\x00``) and each record is length-prefixed + crc32-framed
      (``repro.core.codec``).  Records may be arbitrary bytes — the
      event stores put whole columnar batch frames in them.

    ``binary=True`` sets the *preferred* format: it applies only when this
    instance appends to an empty (or brand-new) file.  A non-empty file's
    format is sniffed from its first bytes and always wins, so existing v1
    segments keep replaying — and keep receiving v1 appends — unchanged.

    This is the shared building block of ``FileEventStore``, the
    partitioned file bus (``repro.bus.FilePartitionedEventStore``:
    per-partition event/committed/DLQ segments) and the state store's
    checkpoint delta logs.

    Torn-tail contract (crash mid-append, §3.4): a write that never completed
    was never acknowledged, so readers must not see it.  ``scan`` consumes
    only *whole* records whose ``parse`` succeeds and stops (without
    advancing) at the first torn or unparseable record — for ``tfb1`` that
    means a truncation at *any* byte offset (mid-varint, mid-crc,
    mid-payload) recovers exactly the prefix of whole crc-valid records.
    ``repair`` truncates such a tail so later appends cannot land beyond it
    and masquerade as part of a valid record.  Writers must ``repair``
    before their first append to a segment they did not create (the owning
    store does this once per open).

    Offsets are byte offsets in both formats (``scan`` works on raw bytes;
    v1 lines decode per record), so callers can persist them format-blind.

    File handles persist across calls (``open`` costs ~ms under syscall
    sandboxes): one lazily-opened append handle, one read handle.  They stay
    valid across truncation and cross-process appends (same inode); a caller
    that *removes* the file must go through ``remove`` so both are dropped.
    """

    __slots__ = ("path", "fsync", "binary", "_format", "_rf", "_af",
                 "append_count", "append_seconds", "replicator", "_dir_dirty")

    def __init__(self, path: str, fsync: bool = True,
                 binary: bool = False) -> None:
        self.path = path
        self.fsync = fsync
        self.binary = binary
        self._format: Optional[str] = None  # sniffed lazily; None = unknown
        self._rf = None
        self._af = None
        # Append accounting for the metrics plane (appends are the store's
        # fsync boundary — tf_log_appends_total / tf_log_append_seconds_total
        # in the shard scrape).  Two perf_counter reads per append, which is
        # already a flush(+fsync) syscall — noise-level overhead.
        self.append_count = 0
        self.append_seconds = 0.0
        # Optional replication sink (repro.bus.replicate): called after each
        # durable local mutation with the byte range / new size, so a replica
        # root can mirror the segment.  Local durability always comes first —
        # the ship happens after flush+fsync.
        self.replicator = None
        self._dir_dirty = False

    def size(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    def active_format(self) -> str:
        """The file's format (``"v1"`` | ``"tfb1"``).  Sniffed from the
        first bytes and cached; an empty (or absent) file answers with this
        instance's *preferred* format without caching — the file only
        commits to a format once bytes land in it.  A 1–4 byte file (e.g. a
        magic header torn by a crash) counts as v1: the text scan finds no
        whole line, so ``repair`` truncates it to empty and the preference
        re-applies."""
        fmt = self._format
        if fmt is None:
            try:
                with open(self.path, "rb") as f:
                    head = f.read(len(codec.MAGIC))
            except OSError:
                head = b""
            if not head:
                return "tfb1" if self.binary else "v1"
            fmt = self._format = "tfb1" if head == codec.MAGIC else "v1"
        return fmt

    def _close(self) -> None:
        for f in (self._rf, self._af):
            if f is not None:
                try:
                    f.close()
                except OSError:  # pragma: no cover
                    pass
        self._rf = self._af = None

    def reset(self) -> None:
        """Drop the cached handles.  Writers sharing a path across processes
        call this when they detect the file was removed/recreated under them
        (e.g. a concurrent delta-log compaction) — the next append/scan
        reopens the *current* inode instead of feeding the unlinked one."""
        self._close()
        self._format = None  # the recreated file may use the other format

    def remove(self) -> None:
        """Delete the file (and drop the cached handles, so a later append
        recreates it instead of writing to the unlinked inode)."""
        self._close()
        self._format = None
        if os.path.exists(self.path):
            os.remove(self.path)
            if self.replicator is not None:
                self.replicator.ship_remove(self.path)

    def append(self, lines: Iterable) -> int:
        """Append records in the file's active format (flush + optional
        fsync): one line per record on v1 (``str`` records only), one
        length+crc frame per record on tfb1 (``str`` records are framed as
        their utf-8 bytes; ``bytes`` pass through).  A tfb1 append to an
        empty file writes the magic header first.  Returns the number of
        bytes written."""
        t0 = time.perf_counter()
        # binary handle + one explicit encode: the text layer would encode
        # too, and a replicated log would then pay a SECOND full encode in
        # ship_append — this way writer and replicator share the same bytes
        fmt = self.active_format()
        if fmt == "tfb1":
            data = b"".join(
                codec.encode_record(
                    r.encode("utf-8") if isinstance(r, str) else r)
                for r in lines)
            if self.size() == 0:
                data = codec.MAGIC + data
                self._format = "tfb1"
        else:
            data = ("\n".join(lines) + "\n").encode("utf-8")
            if self._format is None:
                self._format = "v1"
        f = self._af
        if f is None:
            if not os.path.exists(self.path):
                # first append creates the file: the directory entry needs
                # its own fsync or a crash right after can lose the file
                # despite the data fsync below (satellite of §3.4 durability)
                self._dir_dirty = True
            f = self._af = open(self.path, "ab")
        f.write(data)
        f.flush()
        if self.fsync:
            os.fsync(f.fileno())
            if self._dir_dirty:
                fsync_dir(os.path.dirname(self.path) or ".")
                self._dir_dirty = False
        self.append_count += 1
        self.append_seconds += time.perf_counter() - t0
        if self.replicator is not None:
            end = f.tell()  # exact even with interleaved O_APPEND writers
            self.replicator.ship_append(self.path, end - len(data), data)
        return len(data)

    def scan(self, parse, offset: int = 0):
        """Parse whole records from ``offset``.  Returns
        ``(records, next_offset)`` where ``next_offset`` is the end of the
        parseable prefix — a torn final record (the append never completed)
        or an unparseable one (a tail that was never repaired) stops the
        scan without advancing past it.

        ``parse`` receives ``str`` lines on a v1 segment (unchanged
        contract) and raw ``bytes`` payloads on a tfb1 segment."""
        size = self.size()
        if size <= offset:
            return [], offset
        fmt = self.active_format()
        f = self._rf
        if f is None:
            try:
                f = self._rf = open(self.path, "rb")
            except OSError:
                return [], offset
        if fmt == "tfb1" and offset < len(codec.MAGIC):
            offset = len(codec.MAGIC)  # skip the sniffed header
            if size <= offset:
                return [], offset
        f.seek(offset)
        chunk = f.read()
        records = []
        valid = offset
        if fmt == "tfb1":
            for payload, end in codec.iter_records(chunk):
                try:
                    records.append(parse(payload))
                except Exception:  # noqa: BLE001 - stop before the frankenrecord
                    # tfcheck: allow[seam-safety] an unparseable payload IS the torn tail: stopping the scan here is the contract, not a swallow
                    break
                valid = offset + end
            return records, valid
        pos = 0
        while True:
            nl = chunk.find(b"\n", pos)
            if nl < 0:
                break
            line = chunk[pos:nl].strip()
            if line:
                try:
                    records.append(parse(line.decode("utf-8")))
                except Exception:  # noqa: BLE001 - frankenline: stop before it
                    # tfcheck: allow[seam-safety] an unparseable line IS the torn tail: stopping the scan here is the contract, not a swallow
                    break
            valid = offset + nl + 1
            pos = nl + 1
        return records, valid

    def truncate(self, size: int) -> None:
        """Drop everything past ``size`` (a known record boundary, e.g. the
        ``next_offset`` of a full ``scan``) so new appends land clean.
        The persistent handles survive: the append handle is in append mode
        (kernel-positioned at EOF per write) and the read handle seeks
        absolutely."""
        if size < self.size():
            with open(self.path, "rb+") as f:
                f.truncate(size)
                f.flush()
                os.fsync(f.fileno())
            if size < len(codec.MAGIC):
                # the (possibly binary) header is gone: the file is free to
                # re-commit to either format on its next append
                self._format = None
            if self.replicator is not None:
                self.replicator.ship_truncate(self.path, size)


    def repair(self, parse):
        """Truncate a torn/unparseable tail (fsynced) so new appends land on
        a clean record boundary.  Returns ``(records, valid_size)``."""
        records, valid = self.scan(parse, 0)
        self.truncate(valid)
        return records, valid


def parse_event_record(rec) -> List[CloudEvent]:
    """Segment-format-blind event-record parse for ``SegmentLog.scan``:
    a v1 line (str) holds one JSON event dict *or* a JSON array of them,
    a tfb1 payload (bytes) holds a columnar batch frame.  Always returns
    a list of events."""
    return codec.events_of(codec.decode_payload(rec))


def append_events(seg: SegmentLog, events) -> int:
    """Append one event batch in ``seg``'s active format: a single
    columnar frame record on tfb1 (one encode for the whole batch — the
    2x-cheaper wire format), one JSON line per event on v1 (the legacy
    layout existing segments keep)."""
    if seg.active_format() == "tfb1":
        return seg.append([codec.encode_frame_payload(events)])
    return seg.append([e.to_json() for e in events])


class EventStore:
    """Interface."""

    def create_stream(self, workflow: str) -> None:
        raise NotImplementedError

    def publish(self, workflow: str, event: CloudEvent) -> None:
        raise NotImplementedError

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        for e in events:
            self.publish(workflow, e)

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        """Return up to ``max_events`` uncommitted events (without removing them)."""
        raise NotImplementedError

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        raise NotImplementedError

    def is_committed(self, workflow: str, event_id: str) -> bool:
        raise NotImplementedError

    def lag(self, workflow: str) -> int:
        """Number of uncommitted events (the KEDA scaling metric)."""
        raise NotImplementedError

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        raise NotImplementedError

    def redrive(self, workflow: str, reasons: Optional[Iterable[str]] = None) -> int:
        """Move DLQ events back into the stream.  ``reasons`` restricts the
        move to entries whose quarantine reason matches (legacy entries
        without metadata count as ``disabled``); None moves all.  Returns the
        number moved."""
        raise NotImplementedError

    def dlq_size(self, workflow: str) -> int:
        raise NotImplementedError

    def dlq_by_reason(self, workflow: str) -> Dict[str, int]:
        """DLQ depth broken down by structured quarantine reason."""
        raise NotImplementedError

    def workflows(self) -> List[str]:
        raise NotImplementedError

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        """All committed events in commit order (event-sourcing replay, §5.3)."""
        raise NotImplementedError


class MemoryEventStore(EventStore):
    """One ``StreamShard`` per workflow (the unpartitioned fast path)."""

    #: ``consume`` only returns pending (uncommitted) events — commit removes
    #: them from the stream — so consumers may skip per-event is_committed
    #: round-trips and dedup only against their in-flight set.
    UNCOMMITTED_ONLY = True

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._shards: Dict[str, StreamShard] = {}

    def _shard(self, workflow: str) -> StreamShard:
        s = self._shards.get(workflow)
        if s is None:
            s = self._shards.setdefault(workflow, StreamShard())
        return s

    def create_stream(self, workflow: str) -> None:
        with self._lock:
            self._shard(workflow)

    def publish(self, workflow: str, event: CloudEvent) -> None:
        stamp_publish_time((event,))
        with self._lock:
            self._shard(workflow).publish((event,))

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        events = list(events)
        stamp_publish_time(events)
        with self._lock:
            self._shard(workflow).publish(events)

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        with self._lock:
            s = self._shards.get(workflow)
            return s.consume(max_events) if s is not None else []

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        ids = set(event_ids)
        if not ids:
            return
        with self._lock:
            self._shard(workflow).commit(ids)

    def is_committed(self, workflow: str, event_id: str) -> bool:
        with self._lock:
            s = self._shards.get(workflow)
            return s.is_committed(event_id) if s is not None else False

    def lag(self, workflow: str) -> int:
        with self._lock:
            s = self._shards.get(workflow)
            return s.lag() if s is not None else 0

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        with self._lock:
            self._shard(workflow).to_dlq(event)

    def redrive(self, workflow: str, reasons: Optional[Iterable[str]] = None) -> int:
        with self._lock:
            s = self._shards.get(workflow)
            return s.redrive(reasons) if s is not None else 0

    def dlq_size(self, workflow: str) -> int:
        with self._lock:
            s = self._shards.get(workflow)
            return s.dlq_size() if s is not None else 0

    def dlq_by_reason(self, workflow: str) -> Dict[str, int]:
        with self._lock:
            s = self._shards.get(workflow)
            return s.dlq_by_reason() if s is not None else {}

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._shards.keys())

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        with self._lock:
            s = self._shards.get(workflow)
            return s.committed_events() if s is not None else []


class FileEventStore(EventStore):
    """Durable append-only event log per workflow + committed-id set.

    Layout: ``<root>/<workflow>.log`` (event segment, append-only),
    ``<root>/<workflow>.committed`` (one event id per line, append-only),
    ``<root>/<workflow>.dlq`` (quarantine segment).  A restarted process
    reconstructs the uncommitted set = log - committed, which is exactly the
    paper's "the event broker will send again uncommitted events" recovery
    semantics.

    ``codec`` picks the wire format for *new* event/DLQ segments:
    ``"binary"`` (default) writes TFB1 columnar batch frames, ``"json"``
    the legacy one-JSON-event-per-line layout.  The format of an existing
    segment is sniffed per file and always wins (``SegmentLog``), so a v1
    root replays — and keeps appending — unchanged under either setting.
    The committed log stays line-oriented text in both modes (ids are the
    audit surface).
    """

    #: Like ``MemoryEventStore``: the pending mirror excludes committed ids
    #: (at load, on refresh, and on commit), so consume never re-delivers a
    #: committed event.
    UNCOMMITTED_ONLY = True

    def __init__(self, root: str, codec: str = "binary") -> None:
        self.root = root
        self.codec = codec
        os.makedirs(root, exist_ok=True)
        self._lock = threading.RLock()
        # In-memory mirrors for speed; the segment logs are the source of truth.
        self._pending: Dict[str, deque] = {}
        self._committed_ids: Dict[str, set] = {}
        self._committed_order: Dict[str, List[CloudEvent]] = {}
        self._dlq: Dict[str, deque] = {}
        self._offsets: Dict[str, int] = {}  # log bytes already mirrored
        self._segs: Dict[str, tuple] = {}   # wf -> (log, committed, dlq)
        self._flocks: Dict[str, object] = {}
        for fn in os.listdir(root):
            if fn.endswith(".log"):
                self._load(fn[: -len(".log")])

    @contextmanager
    def _wf_flock(self, workflow: str):
        """Cross-process writer lock per workflow (``<wf>.lock``): appends
        and the torn-tail repair in ``publish_batch`` hold it, so any bytes
        past the parseable prefix under the lock belong to a *dead* writer
        (a live one would be holding the lock) and are safe to truncate."""
        if fcntl is None:  # pragma: no cover - non-POSIX
            yield
            return
        f = self._flocks.get(workflow)
        if f is None:
            safe = workflow.replace("/", "_")
            f = open(os.path.join(self.root, safe + ".lock"), "a")
            self._flocks[workflow] = f
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(f.fileno(), fcntl.LOCK_UN)

    def refresh(self, workflow: str) -> int:
        """Pick up events appended by *other* store instances sharing the log
        (e.g. a crashed worker's still-running tasks publishing terminations).
        Returns the number of new events mirrored."""
        with self._lock:
            log, _, _ = self._seglogs(workflow)
            batches, off = log.scan(parse_event_record,
                                    self._offsets.get(workflow, 0))
            self._offsets[workflow] = off
            new = [e for b in batches for e in b]
            if not new:
                return 0
            committed = self._committed_ids.get(workflow, set())
            known = {e.id for e in self._pending.get(workflow, ())}
            known |= {e.id for e in self._dlq.get(workflow, ())}
            n = 0
            for ev in new:
                if ev.id in committed or ev.id in known:
                    continue
                self._pending.setdefault(workflow, deque()).append(ev)
                n += 1
            return n

    # -- persistence helpers -------------------------------------------------
    def _paths(self, wf: str):
        safe = wf.replace("/", "_")
        return (
            os.path.join(self.root, f"{safe}.log"),
            os.path.join(self.root, f"{safe}.committed"),
            os.path.join(self.root, f"{safe}.dlq"),
        )

    def _seglogs(self, wf: str):
        segs = self._segs.get(wf)
        if segs is None:
            log_p, com_p, dlq_p = self._paths(wf)
            binary = self.codec == "binary"
            segs = (SegmentLog(log_p, binary=binary), SegmentLog(com_p),
                    SegmentLog(dlq_p, binary=binary))
            self._segs[wf] = segs
        return segs

    def _load(self, wf: str) -> None:
        log, com, dlq_seg = self._seglogs(wf)
        # A torn tail (crash mid-append) was never acknowledged: repair drops
        # it so this instance's appends land on a clean record boundary.
        # Under the writer flock — a tail that merely *looks* torn could be
        # a live writer's in-flight append, and truncating that would
        # destroy an fsync-acknowledged publish.
        with self._wf_flock(wf):
            batches, log_size = log.repair(parse_event_record)
            events = [e for b in batches for e in b]
            committed = set(com.repair(str)[0])
            dlq: deque = deque(
                e for b in dlq_seg.repair(parse_event_record)[0] for e in b)
        by_id = {e.id: e for e in events}
        self._committed_ids[wf] = committed
        self._committed_order[wf] = [by_id[i] for i in committed if i in by_id]
        self._dlq[wf] = dlq
        dlq_ids = {e.id for e in dlq}
        self._pending[wf] = deque(
            e for e in events if e.id not in committed and e.id not in dlq_ids
        )
        self._offsets[wf] = log_size

    # -- EventStore ----------------------------------------------------------
    def create_stream(self, workflow: str) -> None:
        with self._lock:
            if workflow not in self._pending:
                self._pending[workflow] = deque()
                self._committed_ids[workflow] = set()
                self._committed_order[workflow] = []
                self._dlq[workflow] = deque()
                log_p, _, _ = self._paths(workflow)
                existed = os.path.exists(log_p)
                open(log_p, "a").close()
                if not existed:
                    fsync_dir(os.path.dirname(log_p) or ".")

    def publish(self, workflow: str, event: CloudEvent) -> None:
        self.publish_batch(workflow, [event])

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        events = list(events)
        if not events:
            return
        stamp_publish_time(events)
        with self._lock:
            self.create_stream(workflow)
            log, _, _ = self._seglogs(workflow)
            with self._wf_flock(workflow):
                self.refresh(workflow)  # mirror foreign appends before ours
                off = self._offsets.get(workflow, 0)
                # Under the writer flock the parseable prefix is exact: any
                # tail past it is a dead writer's torn fragment (never
                # acknowledged — fsync cannot have returned) and must go, or
                # our append would fuse with it into an unparseable line.
                log.truncate(off)
                self._offsets[workflow] = off + append_events(log, events)
            # A re-published copy of a committed id must not re-enter the
            # pending mirror (UNCOMMITTED_ONLY contract); the log append above
            # is harmless — _load filters committed ids on recovery.
            committed = self._committed_ids.get(workflow)
            if committed:
                events = [e for e in events if e.id not in committed]
            self._pending[workflow].extend(events)

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        with self._lock:
            self.refresh(workflow)
            q = self._pending.get(workflow)
            if not q:
                return []
            n = min(len(q), max_events)
            return [q[i] for i in range(n)]

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        ids = set(event_ids)
        if not ids:
            return
        with self._lock:
            _, com, _ = self._seglogs(workflow)
            with self._wf_flock(workflow):
                com.append(sorted(ids))
            self._committed_ids.setdefault(workflow, set()).update(ids)
            keep = deque()
            for e in self._pending.get(workflow, deque()):
                if e.id in ids:
                    self._committed_order.setdefault(workflow, []).append(e)
                else:
                    keep.append(e)
            self._pending[workflow] = keep

    def is_committed(self, workflow: str, event_id: str) -> bool:
        with self._lock:
            return event_id in self._committed_ids.get(workflow, set())

    def lag(self, workflow: str) -> int:
        with self._lock:
            self.refresh(workflow)
            q = self._pending.get(workflow)
            return len(q) if q else 0

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        with self._lock:
            _, _, dlq_seg = self._seglogs(workflow)
            with self._wf_flock(workflow):
                # the batch encoder even for a single event: quarantine and
                # publish share one append shape per format
                append_events(dlq_seg, [event])
            self._dlq.setdefault(workflow, deque()).append(event)
            q = self._pending.get(workflow)
            if q:
                self._pending[workflow] = deque(e for e in q if e.id != event.id)

    def redrive(self, workflow: str, reasons: Optional[Iterable[str]] = None) -> int:
        from .policy import reason_matches

        with self._lock:
            dlq = self._dlq.get(workflow)
            if not dlq:
                return 0
            moved = [e for e in dlq if reason_matches(e, reasons)]
            if not moved:
                return 0
            kept = [e for e in dlq if not reason_matches(e, reasons)]
            self._pending.setdefault(workflow, deque()).extend(moved)
            dlq.clear()
            dlq.extend(kept)
            _, _, dlq_seg = self._seglogs(workflow)
            # The .dlq segment is append-only; a (possibly partial) redrive
            # rewrites it to the survivors so a restart reconstructs the
            # same quarantine set.
            with self._wf_flock(workflow):
                dlq_seg.remove()
                if kept:
                    append_events(dlq_seg, kept)
            return len(moved)

    def dlq_size(self, workflow: str) -> int:
        with self._lock:
            return len(self._dlq.get(workflow, ()))

    def dlq_by_reason(self, workflow: str) -> Dict[str, int]:
        from .policy import dlq_reason

        with self._lock:
            out: Dict[str, int] = {}
            for e in self._dlq.get(workflow, ()):
                r = dlq_reason(e)
                out[r] = out.get(r, 0) + 1
            return out

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._pending.keys())

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        with self._lock:
            return list(self._committed_order.get(workflow, []))
