"""Workflow/trigger/context database (paper §4: "A Database, responsible for
storing workflow information, such as triggers, context, etc.").

Checkpointing contract (§3.4): each time a trigger fires, the contexts of all
activated triggers are persisted *before* the consumed events are committed to
the event store.  A restarted worker therefore reloads trigger definitions and
the last checkpointed contexts, and replays uncommitted events on top.

Incremental checkpoints: the worker emits per-trigger *deltas*
(``TriggerContext.take_delta``) via ``put_contexts_delta``.  The durable
store appends them to a per-workflow JSONL context log — one small
append+fsync per checkpoint instead of rewriting every context — and
periodically compacts the log back into the base ``contexts.json``.
``get_contexts`` replays base + log, so crash recovery sees exactly the
state of the last acknowledged checkpoint.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional

from .context import apply_context_delta


class StateStore:
    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def delete_workflow(self, workflow: str) -> None:
        raise NotImplementedError

    def workflows(self) -> List[str]:
        raise NotImplementedError

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        raise NotImplementedError

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        """Persist a batch of trigger specs.  Stores should override this with
        a single atomic write; the default degrades to per-trigger puts."""
        for tid, spec in specs.items():
            self.put_trigger(workflow, tid, spec)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        """Atomically persist a batch of trigger contexts (the checkpoint)."""
        raise NotImplementedError

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        """Persist a batch of context *deltas* (``TriggerContext.take_delta``
        records).  Default: read-modify-write through ``put_contexts`` so any
        third-party store keeps working; the built-in stores override with
        O(delta) fast paths."""
        stored = self.get_contexts(workflow)
        merged = {
            tid: apply_context_delta(stored.get(tid, {}), delta)
            for tid, delta in deltas.items()
        }
        self.put_contexts(workflow, merged)

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError


class MemoryStateStore(StateStore):
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._wf: Dict[str, Dict[str, Any]] = {}
        self._triggers: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self._contexts: Dict[str, Dict[str, Dict[str, Any]]] = {}

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._wf[workflow] = dict(meta)
            self._triggers.setdefault(workflow, {})
            self._contexts.setdefault(workflow, {})

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            return self._wf.get(workflow)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            self._wf.pop(workflow, None)
            self._triggers.pop(workflow, None)
            self._contexts.pop(workflow, None)

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._wf.keys())

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        with self._lock:
            self._triggers.setdefault(workflow, {})[trigger_id] = spec

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            self._triggers.setdefault(workflow, {}).update(specs)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._triggers.get(workflow, {}).items()}

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            store = self._contexts.setdefault(workflow, {})
            for tid, ctx in contexts.items():
                store[tid] = json.loads(json.dumps(ctx))  # deep copy, JSON-safe

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            store = self._contexts.setdefault(workflow, {})
            # deep-copy the *delta* (isolating the worker's live objects),
            # not the merged state — keeps the checkpoint O(delta).
            safe = json.loads(json.dumps(deltas))
            for tid, delta in safe.items():
                store[tid] = apply_context_delta(store.get(tid, {}), delta)

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._contexts.get(workflow, {}).items()}


class FileStateStore(StateStore):
    """Durable JSON-file state store.

    Layout per workflow directory:

    * ``meta.json`` / ``triggers.json`` — atomic full-file writes.
    * ``contexts.json`` — the compacted context base map.
    * ``contexts.delta.jsonl`` — append-only checkpoint log; each line is one
      ``put_contexts_delta`` batch (``{tid: delta, ...}``).  Readers replay
      base + log; the log is folded back into ``contexts.json`` every
      ``compact_every`` checkpoints, or as soon as it exceeds
      ``compact_bytes`` bytes (whichever hits first; a full ``put_contexts``
      also compacts).  The byte trigger bounds recovery-replay time for
      long-lived workflows with *large* per-checkpoint deltas — a fixed
      line count alone lets the log grow with delta size.
      A torn final line from a mid-append crash is ignored on replay —
      its checkpoint was never acknowledged, so the §3.4 contract holds and
      the broker redelivers the corresponding events.
    """

    def __init__(self, root: str, compact_every: int = 256,
                 compact_bytes: Optional[int] = None) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.RLock()
        self.compact_every = compact_every
        self.compact_bytes = compact_bytes
        self._delta_lines: Dict[str, int] = {}
        self._delta_bytes: Dict[str, int] = {}

    def _dir(self, wf: str) -> str:
        d = os.path.join(self.root, wf.replace("/", "_"))
        os.makedirs(d, exist_ok=True)
        return d

    def _write(self, path: str, obj: Any) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(obj, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic

    def _read(self, path: str, default: Any) -> Any:
        if not os.path.exists(path):
            return default
        with open(path) as f:
            return json.load(f)

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._write(os.path.join(self._dir(workflow), "meta.json"), meta)

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "meta.json")
            return self._read(p, None)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            d = os.path.join(self.root, workflow.replace("/", "_"))
            if os.path.isdir(d):
                for fn in os.listdir(d):
                    os.remove(os.path.join(d, fn))
                os.rmdir(d)
            self._delta_lines.pop(workflow, None)
            self._delta_bytes.pop(workflow, None)

    def workflows(self) -> List[str]:
        with self._lock:
            return [d for d in os.listdir(self.root) if os.path.isdir(os.path.join(self.root, d))]

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        self.put_triggers(workflow, {trigger_id: spec})

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        """One read + one atomic write for the whole batch (the worker's
        dirty-trigger checkpoint), instead of a rewrite+fsync per trigger."""
        with self._lock:
            p = os.path.join(self._dir(workflow), "triggers.json")
            triggers = self._read(p, {})
            triggers.update(specs)
            self._write(p, triggers)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "triggers.json")
            return self._read(p, {})

    # -- contexts: compacted base + append-only delta log ---------------------
    def _ctx_paths(self, wf_dir: str):
        return (os.path.join(wf_dir, "contexts.json"),
                os.path.join(wf_dir, "contexts.delta.jsonl"))

    def _read_delta_log(self, log_p: str):
        """Replay the delta log.  Returns ``(batches, valid_bytes)`` where
        ``valid_bytes`` is the length of the parseable prefix — a torn line
        from a crash mid-append (never acknowledged) ends it."""
        if not os.path.exists(log_p):
            return [], 0
        batches: List[Dict[str, Any]] = []
        valid = 0
        with open(log_p) as f:  # json.dumps writes ASCII: chars == bytes
            for line in f:
                if not line.endswith("\n"):
                    # the final append never completed (fsync cannot have
                    # returned), even if the fragment happens to parse —
                    # the checkpoint was not acknowledged.
                    break
                stripped = line.strip()
                if stripped:
                    try:
                        batches.append(json.loads(stripped))
                    except ValueError:
                        break
                valid += len(line)
        return batches, valid

    def _repair_delta_log(self, workflow: str, log_p: str) -> int:
        """Drop a torn tail *before* new checkpoints are appended after it
        (they would otherwise be acknowledged but skipped on every replay).
        Returns the number of valid batches in the log."""
        batches, valid = self._read_delta_log(log_p)
        if os.path.exists(log_p) and valid < os.path.getsize(log_p):
            with open(log_p, "r+") as f:
                f.truncate(valid)
                f.flush()
                os.fsync(f.fileno())
        return len(batches)

    def _merged_contexts(self, wf_dir: str) -> Dict[str, Dict[str, Any]]:
        base_p, log_p = self._ctx_paths(wf_dir)
        contexts = self._read(base_p, {})
        for batch in self._read_delta_log(log_p)[0]:
            for tid, delta in batch.items():
                contexts[tid] = apply_context_delta(contexts.get(tid, {}), delta)
        return contexts

    def _compact(self, workflow: str, wf_dir: str,
                 contexts: Dict[str, Dict[str, Any]]) -> None:
        base_p, log_p = self._ctx_paths(wf_dir)
        self._write(base_p, contexts)
        if os.path.exists(log_p):
            os.remove(log_p)
        self._delta_lines[workflow] = 0
        self._delta_bytes[workflow] = 0

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            wf_dir = self._dir(workflow)
            stored = self._merged_contexts(wf_dir)
            stored.update(contexts)
            self._compact(workflow, wf_dir, stored)

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            wf_dir = self._dir(workflow)
            _, log_p = self._ctx_paths(wf_dir)
            n = self._delta_lines.get(workflow)
            if n is None:
                # first touch after a restart (or after a failed append):
                # truncate any torn tail before appending, or later
                # checkpoints would land beyond it and be silently skipped
                # by every replay.
                n = self._repair_delta_log(workflow, log_p)
                self._delta_bytes[workflow] = (
                    os.path.getsize(log_p) if os.path.exists(log_p) else 0)
            line = json.dumps(deltas, separators=(",", ":")) + "\n"
            try:
                with open(log_p, "a") as f:
                    f.write(line)
                    f.flush()
                    os.fsync(f.fileno())
            except Exception:
                # the append may have landed partially: force a repair pass
                # before the next append so the torn fragment is truncated
                self._delta_lines.pop(workflow, None)
                raise
            self._delta_lines[workflow] = n + 1
            nbytes = self._delta_bytes.get(workflow, 0) + len(line)
            self._delta_bytes[workflow] = nbytes
            if self._delta_lines[workflow] >= self.compact_every or (
                    self.compact_bytes is not None
                    and nbytes >= self.compact_bytes):
                self._compact(workflow, wf_dir, self._merged_contexts(wf_dir))

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            wf_dir = os.path.join(self.root, workflow.replace("/", "_"))
            if not os.path.isdir(wf_dir):
                return {}
            return self._merged_contexts(wf_dir)
