"""Workflow/trigger/context database (paper §4: "A Database, responsible for
storing workflow information, such as triggers, context, etc.").

Checkpointing contract (§3.4): each time a trigger fires, the contexts of all
activated triggers are persisted *before* the consumed events are committed to
the event store.  A restarted worker therefore reloads trigger definitions and
the last checkpointed contexts, and replays uncommitted events on top.

Incremental checkpoints: the worker emits per-trigger *deltas*
(``TriggerContext.take_delta``) via ``put_contexts_delta``.  The durable
store appends them to a per-workflow JSONL context log — one small
append+fsync per checkpoint instead of rewriting every context — and
periodically compacts the log back into the base ``contexts.json``.
``get_contexts`` replays base + log, so crash recovery sees exactly the
state of the last acknowledged checkpoint.
"""
from __future__ import annotations

import json
import os
import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: single-process only
    fcntl = None  # type: ignore[assignment]

from .context import apply_context_delta
from .eventstore import SegmentLog


class StateStore:
    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def delete_workflow(self, workflow: str) -> None:
        raise NotImplementedError

    def workflows(self) -> List[str]:
        raise NotImplementedError

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        raise NotImplementedError

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        """Persist a batch of trigger specs.  Stores should override this with
        a single atomic write; the default degrades to per-trigger puts."""
        for tid, spec in specs.items():
            self.put_trigger(workflow, tid, spec)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        """Atomically persist a batch of trigger contexts (the checkpoint)."""
        raise NotImplementedError

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        """Persist a batch of context *deltas* (``TriggerContext.take_delta``
        records).  Default: read-modify-write through ``put_contexts`` so any
        third-party store keeps working; the built-in stores override with
        O(delta) fast paths."""
        stored = self.get_contexts(workflow)
        merged = {
            tid: apply_context_delta(stored.get(tid, {}), delta)
            for tid, delta in deltas.items()
        }
        self.put_contexts(workflow, merged)

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError


class MemoryStateStore(StateStore):
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._wf: Dict[str, Dict[str, Any]] = {}
        self._triggers: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self._contexts: Dict[str, Dict[str, Dict[str, Any]]] = {}

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._wf[workflow] = dict(meta)
            self._triggers.setdefault(workflow, {})
            self._contexts.setdefault(workflow, {})

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            return self._wf.get(workflow)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            self._wf.pop(workflow, None)
            self._triggers.pop(workflow, None)
            self._contexts.pop(workflow, None)

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._wf.keys())

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        with self._lock:
            self._triggers.setdefault(workflow, {})[trigger_id] = spec

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            self._triggers.setdefault(workflow, {}).update(specs)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._triggers.get(workflow, {}).items()}

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            store = self._contexts.setdefault(workflow, {})
            for tid, ctx in contexts.items():
                store[tid] = json.loads(json.dumps(ctx))  # deep copy, JSON-safe

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            store = self._contexts.setdefault(workflow, {})
            # deep-copy the *delta* (isolating the worker's live objects),
            # not the merged state — keeps the checkpoint O(delta).
            safe = json.loads(json.dumps(deltas))
            for tid, delta in safe.items():
                store[tid] = apply_context_delta(store.get(tid, {}), delta)

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._contexts.get(workflow, {}).items()}


class FileStateStore(StateStore):
    """Durable JSON-file state store.

    Layout per workflow directory:

    * ``meta.json`` / ``triggers.json`` — atomic full-file writes.
    * ``contexts.json`` — the compacted context base map.
    * ``contexts.delta[.<scope>].jsonl`` — append-only checkpoint log(s);
      each line is one ``put_contexts_delta`` batch (``{tid: delta, ...}``).
      Readers replay base + every log; a writer's own log is folded back into
      ``contexts.json`` every ``compact_every`` checkpoints, or as soon as it
      exceeds ``compact_bytes`` bytes (whichever hits first; a full
      ``put_contexts`` also compacts).  The byte trigger bounds
      recovery-replay time for long-lived workflows with *large*
      per-checkpoint deltas — a fixed line count alone lets the log grow with
      delta size.  A torn final line from a mid-append crash is ignored on
      replay — its checkpoint was never acknowledged, so the §3.4 contract
      holds and the broker redelivers the corresponding events.

    Multi-process checkpointing (the process shard runtime): each writer
    process constructs its store with a distinct ``scope`` and appends to its
    *own* delta log, so concurrent shard checkpoints never contend on one
    JSONL file (and never interleave mid-line).  Correctness relies on the
    runtime's ownership discipline: between two ``compact()`` points, a given
    trigger id is checkpointed by at most one scope (trigger contexts live
    with their subject-partition owner), so the replay order *across* scope
    logs is immaterial.  The pool folds all logs into the base
    (``compact()``) at every ownership change — rebalance, crash, restart —
    before new owners write.  Cross-process safety uses a per-workflow file
    lock (``state.lock``): appends and reads take it shared, compaction and
    trigger/meta read-modify-writes take it exclusive.
    """

    def __init__(self, root: str, compact_every: int = 256,
                 compact_bytes: Optional[int] = None,
                 scope: Optional[str] = None,
                 replicator=None) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.RLock()
        self.compact_every = compact_every
        self.compact_bytes = compact_bytes
        self.scope = scope
        # host-loss fault domain: a ``repro.bus.replicate.ReplicationClient``
        # rooted at this store's ``root`` — checkpoint delta appends ship as
        # segment frames, atomic JSON writes ship as whole-file puts, so a
        # replica root holds the same recoverable state this disk does
        self.replicator = replicator
        self._delta_lines: Dict[str, int] = {}
        self._delta_bytes: Dict[str, int] = {}
        self._flocks: Dict[str, Any] = {}
        self._own_logs: Dict[str, SegmentLog] = {}

    def _dir(self, wf: str) -> str:
        d = os.path.join(self.root, wf.replace("/", "_"))
        os.makedirs(d, exist_ok=True)
        return d

    @contextmanager
    def _flock(self, workflow: str, exclusive: bool):
        """Cross-process lock on the workflow's state directory.  Shared for
        delta appends / merged reads (they touch disjoint files or read
        atomically-replaced ones), exclusive for compaction and
        read-modify-write of the shared JSON files."""
        if fcntl is None:  # non-POSIX: in-process RLock is all we have
            yield
            return
        f = self._flocks.get(workflow)
        if f is None:
            f = open(os.path.join(self._dir(workflow), "state.lock"), "a")
            self._flocks[workflow] = f
        fcntl.flock(f.fileno(),
                    fcntl.LOCK_EX if exclusive else fcntl.LOCK_SH)
        try:
            yield
        finally:
            fcntl.flock(f.fileno(), fcntl.LOCK_UN)

    def _write(self, path: str, obj: Any) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(obj, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic
        if self.replicator is not None:
            self.replicator.ship_put(path, json.dumps(obj))

    def _read(self, path: str, default: Any) -> Any:
        if not os.path.exists(path):
            return default
        with open(path) as f:
            return json.load(f)

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._write(os.path.join(self._dir(workflow), "meta.json"), meta)

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "meta.json")
            return self._read(p, None)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            f = self._flocks.pop(workflow, None)
            if f is not None:
                f.close()
            own = self._own_logs.pop(workflow, None)
            if own is not None:
                own.reset()
            d = os.path.join(self.root, workflow.replace("/", "_"))
            if os.path.isdir(d):
                for fn in os.listdir(d):
                    os.remove(os.path.join(d, fn))
                os.rmdir(d)
            self._delta_lines.pop(workflow, None)
            self._delta_bytes.pop(workflow, None)

    def workflows(self) -> List[str]:
        with self._lock:
            return [d for d in os.listdir(self.root) if os.path.isdir(os.path.join(self.root, d))]

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        self.put_triggers(workflow, {trigger_id: spec})

    def put_triggers(self, workflow: str, specs: Dict[str, Dict[str, Any]]) -> None:
        """One read + one atomic write for the whole batch (the worker's
        dirty-trigger checkpoint), instead of a rewrite+fsync per trigger.
        Exclusive-locked: concurrent shard processes each persisting their
        dirty triggers must not lose each other's read-modify-write."""
        with self._lock, self._flock(workflow, exclusive=True):
            p = os.path.join(self._dir(workflow), "triggers.json")
            triggers = self._read(p, {})
            triggers.update(specs)
            self._write(p, triggers)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "triggers.json")
            return self._read(p, {})

    # -- contexts: compacted base + append-only delta log(s) -------------------
    def _base_path(self, wf_dir: str) -> str:
        return os.path.join(wf_dir, "contexts.json")

    def _own_log_name(self) -> str:
        return ("contexts.delta.%s.jsonl" % self.scope.replace("/", "_")
                if self.scope else "contexts.delta.jsonl")

    def _own_log(self, workflow: str, wf_dir: str) -> SegmentLog:
        log = self._own_logs.get(workflow)
        if log is None:
            log = SegmentLog(os.path.join(wf_dir, self._own_log_name()))
            log.replicator = self.replicator
            self._own_logs[workflow] = log
        return log

    def _all_logs(self, wf_dir: str) -> List[SegmentLog]:
        if not os.path.isdir(wf_dir):
            return []
        names = sorted(
            fn for fn in os.listdir(wf_dir)
            if fn.startswith("contexts.delta") and fn.endswith(".jsonl"))
        logs = [SegmentLog(os.path.join(wf_dir, fn)) for fn in names]
        for log in logs:
            # compaction removals mirror too — other scopes' logs are
            # dropped on the replica when the compactor drops them locally
            log.replicator = self.replicator
        return logs

    def _merged_contexts(self, wf_dir: str) -> Dict[str, Dict[str, Any]]:
        """Base + every delta log.  Between compaction points a trigger id is
        written by at most one scope (the runtime's ownership discipline), so
        cross-log replay order is immaterial; within a log, append order is
        preserved.  Torn tails (unacknowledged checkpoints) are skipped."""
        contexts = self._read(self._base_path(wf_dir), {})
        for log in self._all_logs(wf_dir):
            for batch in log.scan(json.loads)[0]:
                for tid, delta in batch.items():
                    contexts[tid] = apply_context_delta(
                        contexts.get(tid, {}), delta)
        return contexts

    def _compact_locked(self, workflow: str, wf_dir: str,
                        extra: Optional[Dict[str, Dict[str, Any]]] = None) -> None:
        """Fold base + all delta logs (+ ``extra``) into the base and drop the
        logs.  Caller holds the exclusive flock.  Idempotent on crash between
        the base write and a log removal: deltas are full-value records, so
        replaying an already-folded log is harmless."""
        contexts = self._merged_contexts(wf_dir)
        if extra:
            contexts.update(extra)
        self._write(self._base_path(wf_dir), contexts)
        own = self._own_logs.get(workflow)
        for log in self._all_logs(wf_dir):
            if own is not None and log.path == own.path:
                own.remove()  # drop cached handles with the inode
            else:
                log.remove()
        self._delta_lines[workflow] = 0
        self._delta_bytes[workflow] = 0

    def compact(self, workflow: str) -> None:
        """Fold every scope's delta log into the compacted base.  The process
        shard runtime calls this at each ownership boundary (rebalance, crash
        recovery, restart) so that afterwards any scope may checkpoint any
        trigger without cross-log ordering ambiguity."""
        with self._lock, self._flock(workflow, exclusive=True):
            self._compact_locked(workflow, self._dir(workflow))

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock, self._flock(workflow, exclusive=True):
            self._compact_locked(workflow, self._dir(workflow), extra=contexts)
        if self.replicator is not None and hasattr(self.replicator, "flush"):
            self.replicator.flush()

    def put_contexts_delta(self, workflow: str, deltas: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            wf_dir = self._dir(workflow)
            log = self._own_log(workflow, wf_dir)
            record = json.dumps(deltas, separators=(",", ":"))
            with self._flock(workflow, exclusive=False):
                n = self._delta_lines.get(workflow)
                if n is None or log.size() != self._delta_bytes.get(workflow):
                    # First touch after a restart, a failed append, OR a
                    # concurrent compaction (another process folded + removed
                    # our log — detected by the size mismatch, and impossible
                    # to race: their EX flock excludes our SH).  Reopen the
                    # current inode and truncate any torn tail of OUR log
                    # before appending, or later checkpoints would land
                    # beyond it and be silently skipped by every replay.
                    log.reset()
                    n = len(log.repair(json.loads)[0])
                    self._delta_bytes[workflow] = log.size()
                try:
                    written = log.append([record])
                except Exception:
                    # the append may have landed partially: force a repair
                    # pass before the next append truncates the torn fragment
                    self._delta_lines.pop(workflow, None)
                    raise
                self._delta_lines[workflow] = n + 1
                nbytes = self._delta_bytes.get(workflow, 0) + written
                self._delta_bytes[workflow] = nbytes
            if self._delta_lines[workflow] >= self.compact_every or (
                    self.compact_bytes is not None
                    and nbytes >= self.compact_bytes):
                # lock upgrade is release-then-acquire; _compact_locked
                # re-reads everything under the exclusive lock, so a
                # concurrent compaction in the gap is benign.
                with self._flock(workflow, exclusive=True):
                    self._compact_locked(workflow, wf_dir)
            if self.replicator is not None and \
                    hasattr(self.replicator, "flush"):
                # checkpoint-before-commit extends to the replica: the
                # delta must be *sent* before the caller commits the events
                # it covers through the (separate) bus client, or a host
                # loss strands a committed event with no checkpointed result
                self.replicator.flush()

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            wf_dir = os.path.join(self.root, workflow.replace("/", "_"))
            if not os.path.isdir(wf_dir):
                return {}
            with self._flock(workflow, exclusive=False):
                return self._merged_contexts(wf_dir)
