"""Workflow/trigger/context database (paper §4: "A Database, responsible for
storing workflow information, such as triggers, context, etc.").

Checkpointing contract (§3.4): each time a trigger fires, the contexts of all
activated triggers are persisted *before* the consumed events are committed to
the event store.  A restarted worker therefore reloads trigger definitions and
the last checkpointed contexts, and replays uncommitted events on top.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional


class StateStore:
    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def delete_workflow(self, workflow: str) -> None:
        raise NotImplementedError

    def workflows(self) -> List[str]:
        raise NotImplementedError

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        """Atomically persist a batch of trigger contexts (the checkpoint)."""
        raise NotImplementedError

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        raise NotImplementedError


class MemoryStateStore(StateStore):
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._wf: Dict[str, Dict[str, Any]] = {}
        self._triggers: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self._contexts: Dict[str, Dict[str, Dict[str, Any]]] = {}

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._wf[workflow] = dict(meta)
            self._triggers.setdefault(workflow, {})
            self._contexts.setdefault(workflow, {})

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            return self._wf.get(workflow)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            self._wf.pop(workflow, None)
            self._triggers.pop(workflow, None)
            self._contexts.pop(workflow, None)

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._wf.keys())

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        with self._lock:
            self._triggers.setdefault(workflow, {})[trigger_id] = spec

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._triggers.get(workflow, {}).items()}

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            store = self._contexts.setdefault(workflow, {})
            for tid, ctx in contexts.items():
                store[tid] = json.loads(json.dumps(ctx))  # deep copy, JSON-safe

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: dict(v) for k, v in self._contexts.get(workflow, {}).items()}


class FileStateStore(StateStore):
    """Durable JSON-file state store: ``<root>/<wf>/{meta,triggers,contexts}.json``."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.RLock()

    def _dir(self, wf: str) -> str:
        d = os.path.join(self.root, wf.replace("/", "_"))
        os.makedirs(d, exist_ok=True)
        return d

    def _write(self, path: str, obj: Any) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(obj, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic

    def _read(self, path: str, default: Any) -> Any:
        if not os.path.exists(path):
            return default
        with open(path) as f:
            return json.load(f)

    def put_workflow(self, workflow: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self._write(os.path.join(self._dir(workflow), "meta.json"), meta)

    def get_workflow(self, workflow: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "meta.json")
            return self._read(p, None)

    def delete_workflow(self, workflow: str) -> None:
        with self._lock:
            d = os.path.join(self.root, workflow.replace("/", "_"))
            if os.path.isdir(d):
                for fn in os.listdir(d):
                    os.remove(os.path.join(d, fn))
                os.rmdir(d)

    def workflows(self) -> List[str]:
        with self._lock:
            return [d for d in os.listdir(self.root) if os.path.isdir(os.path.join(self.root, d))]

    def put_trigger(self, workflow: str, trigger_id: str, spec: Dict[str, Any]) -> None:
        with self._lock:
            p = os.path.join(self._dir(workflow), "triggers.json")
            triggers = self._read(p, {})
            triggers[trigger_id] = spec
            self._write(p, triggers)

    def get_triggers(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "triggers.json")
            return self._read(p, {})

    def put_contexts(self, workflow: str, contexts: Dict[str, Dict[str, Any]]) -> None:
        with self._lock:
            p = os.path.join(self._dir(workflow), "contexts.json")
            stored = self._read(p, {})
            stored.update(contexts)
            self._write(p, stored)

    def get_contexts(self, workflow: str) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            p = os.path.join(self.root, workflow.replace("/", "_"), "contexts.json")
            return self._read(p, {})
