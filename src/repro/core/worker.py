"""TF-Worker: the per-workflow event processor (paper §4).

Processing pipeline per batch (§3.2 trigger life-cycle + §3.4 fault tolerance):

  consume → dedup by event id → **group** by (subject, type) →
  **activate** (evaluate Conditions over event *slices* — the batch plane) →
  **fire** (run Action; transient triggers deactivate) →
  checkpoint: persist context *deltas* → commit processed events → redrive DLQ.

The batch plane: instead of a per-event interpreter walk (registry dispatch +
context wrap per event), a consumed batch is grouped once by
``(subject, type)`` and each matching trigger evaluates its condition over
the whole arrival-ordered slice via the batched-condition protocol
(``conditions.BATCHED_CONDITIONS``).  Groups that are provably pure counting
are further folded into one segmented-sum array op by the ``VectorJoinPlane``
(the ``event_join`` kernel's algorithm).  Conditions without a batched
implementation degrade to the identical scalar path per slice.  Set
``batch_plane=False`` to run the legacy per-event interpreter (kept as the
parity oracle).

The action plane (the fire path made O(batch)): a *fire-run* condition
(``conditions.FIRE_RUN_CONDITIONS``) reports every fire position of a slice
in one call and a batched action (``actions.BATCHED_ACTIONS``) handles the
whole run of fires in one call — so a trigger that fires on (nearly) every
event (Table-1 noop, fan-out produce) costs two Python calls per slice
instead of one condition + one action round-trip per event.  Gated per
worker by ``action_plane``; transient triggers and scalar-only actions
(``invoke``/``intercepted``/``pyfunc``) always keep the per-fire path.

Ordering contract: slices preserve per-subject arrival order (the bus's
per-key guarantee); cross-subject interleaving within a batch is relaxed —
the at-least-once event store contract already requires consumers to
tolerate reordering and redelivery, and parity tests pin the semantics.

Crash-consistency contract: contexts are persisted *before* events are
committed, so after a crash the event broker re-delivers uncommitted events
and replaying them over the last checkpointed contexts reconstructs the state
(conditions are idempotent; the built-in aggregators can additionally dedup by
event id inside their context for exactly-once counting across the
persist/commit window).  Checkpoints are incremental: only dirty context
*keys* (``TriggerContext.take_delta``) and dirty trigger ids are written.

Out-of-order sequences: an event whose trigger exists but is *disabled* goes
to the Dead Letter Queue and is redriven when any trigger state changes
(exactly the A→B example in §3.4).
"""
from __future__ import annotations

import os
import threading
import time
import traceback
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .actions import (ACTIONS, BATCHED_ACTIONS, batchable_action, run_action,
                      run_condition)
from .batch import CLAIMABLE_CONDITIONS
from .conditions import BATCHED_CONDITIONS, CONDITIONS, FIRE_RUN_CONDITIONS
from .context import TriggerContext
from .events import CloudEvent
from ..obs.trace import inject as _trace_inject
from .eventstore import EventStore
from .functions import FunctionBackend
from .policy import (ActionTimeout, AUTO_REDRIVE_REASONS, RETRY_STATE_KEY,
                     REASON_ACTION_ERROR, REASON_CONDITION_ERROR,
                     REASON_DISABLED, REASON_TIMEOUT, RetryPolicy,
                     call_with_timeout, quarantined, reason_counter_name)
from .statestore import StateStore
from .triggers import Trigger


class WorkerStats:
    """Hot-loop counters.  ``snapshot``/``merge``/``fold`` are THE folding
    helpers — both shard pools (thread and process) aggregate lifetime
    totals through them, so the two runtimes can't drift on what a stat
    means or which keys exist."""

    FIELDS = ("events_processed", "activations", "fires", "batches",
              "dlq_events", "action_retries", "poison_events",
              "action_timeouts")
    __slots__ = FIELDS

    def __init__(self) -> None:
        self.events_processed = 0
        self.activations = 0
        self.fires = 0
        self.batches = 0
        self.dlq_events = 0
        # failure-policy plane (core.policy): failed runs rescheduled under a
        # RetryPolicy, events quarantined on budget exhaustion, and attempts
        # cut short by the action watchdog
        self.action_retries = 0
        self.poison_events = 0
        self.action_timeouts = 0

    def snapshot(self) -> Dict[str, int]:
        return {f: getattr(self, f) for f in self.FIELDS}

    def merge(self, other) -> "WorkerStats":
        """Add another ``WorkerStats`` (or a snapshot dict) into this one."""
        if isinstance(other, WorkerStats):
            other = other.snapshot()
        for f in self.FIELDS:
            setattr(self, f, getattr(self, f) + other.get(f, 0))
        return self

    @staticmethod
    def fold(into: Dict[str, float], frm) -> Dict[str, float]:
        """Accumulate a stats mapping (snapshot or ``WorkerStats``) into a
        plain dict, preserving rider keys (e.g. the process runtime's
        ``cpu_seconds``) that travel alongside the core fields."""
        if isinstance(frm, WorkerStats):
            frm = frm.snapshot()
        for k, v in frm.items():
            into[k] = into.get(k, 0) + v
        return into


class _Entry:
    """Compiled per-subject dispatch entry: registry lookups and the trigger's
    context resolved once (invalidated on any trigger-structure change)."""

    __slots__ = ("trg", "ctx", "cspec", "cname", "cfn", "bfn", "rfn",
                 "aspec", "afn", "bafn", "policy")

    def __init__(self, trg: Trigger, ctx: TriggerContext) -> None:
        self.trg = trg
        self.ctx = ctx
        self.cspec = trg.condition
        self.cname = self.cspec["name"]
        self.cfn = CONDITIONS.get(self.cname) or (
            lambda c, e, s: run_condition(s, c, e))  # late-registered: raise like generic path
        self.bfn = BATCHED_CONDITIONS.get(self.cname)
        self.rfn = FIRE_RUN_CONDITIONS.get(self.cname)
        self.aspec = trg.action
        self.afn = ACTIONS.get(self.aspec["name"]) or (
            lambda c, e, s: run_action(s, c, e))
        # the trigger's compiled RetryPolicy (None ⇒ pre-policy semantics:
        # failures print and the event commits as consumed)
        self.policy = (RetryPolicy.from_dict(trg.retry_policy)
                       if trg.retry_policy else None)
        # action-plane eligibility covers the whole action tree: a chain
        # wrapping a scalar-only sub-action must keep the per-fire path.
        # A per-attempt watchdog (``action_timeout``) needs per-fire calls,
        # so it pins the trigger to the scalar fire path at compile time —
        # zero cost in the hot loop.
        self.bafn = (BATCHED_ACTIONS.get(self.aspec["name"])
                     if batchable_action(self.aspec)
                     and (self.policy is None
                          or self.policy.action_timeout is None) else None)

    def matches(self, etype: str) -> bool:
        """Live candidacy check: enabled and (no filter or type match)."""
        trg = self.trg
        return trg.enabled and (not trg.event_type or trg.event_type == etype)


class TFWorker:
    def __init__(
        self,
        workflow: str,
        event_store: EventStore,
        state_store: StateStore,
        backend: FunctionBackend,
        batch_size: int = 512,
        commit_policy: str = "on_fire",  # "on_fire" (paper) | "every_batch"
        keep_event_log: bool = True,
        timers=None,
        partitions: Optional[Iterable[int]] = None,
        batch_plane: bool = True,
        action_plane: bool = True,
        vector_join: Optional[str] = None,
        metrics: bool = True,
        tracer=None,
    ) -> None:
        self.workflow = workflow
        self.event_store = event_store
        self.state_store = state_store
        self.backend = backend
        self.timers = timers
        self.batch_size = batch_size
        self.commit_policy = commit_policy
        self.keep_event_log = keep_event_log
        # Assigned partition subset (consumer-group shard mode).  None means
        # "the whole stream" (the classic single-worker deployment).  A shard
        # *owns* its partitions exclusively, so consume() never races another
        # consumer of the same events and per-event is_committed checks are
        # unnecessary when the store only hands out uncommitted events.
        self.partitions: Optional[tuple] = (
            tuple(partitions) if partitions is not None else None
        )
        # Hoisted once: partition routing for inline sink-event ownership,
        # bound to this workflow (partitioned stores may pin a per-workflow
        # partition count, so subject→partition depends on the workflow).
        _pf = getattr(event_store, "partition_for", None)
        self._partition_for = (
            None if _pf is None
            else lambda subject, _pf=_pf, _wf=workflow: _pf(subject, _wf))

        self.lock = threading.RLock()
        self.triggers: Dict[str, Trigger] = {}
        self._by_subject: Dict[str, List[Trigger]] = {}
        self._contexts: Dict[str, TriggerContext] = {}
        self._dispatch: Dict[str, List[_Entry]] = {}
        self._seen: set = set()          # processed-but-uncommitted event ids
        # event ids already counted in stats.dlq_events: a quarantined event
        # that cycles through redrive back into the DLQ is one DLQ'd event,
        # not one per cycle (ids are released once the event finally commits)
        self._dlq_counted: set = set()
        # failure-policy plane (core.policy).  ``_retry_after`` is the local
        # backoff timer wheel: event id → monotonic not-before; a deferred
        # event stays pending in the store and is filtered out of consumed
        # batches until its deadline (no hot redelivery; deadlines are
        # volatile, so a restarted worker retries immediately — the durable
        # attempt counter, not the clock, bounds the budget).  ``_no_commit``
        # collects ids that must not commit this batch (deferred or
        # quarantined mid-slice); ``_policy_dirty`` forces a checkpoint when
        # retry bookkeeping touched a context even though nothing fired.
        self._retry_after: Dict[str, float] = {}
        self._no_commit: set = set()
        self._policy_dirty = False
        self._policy_cache: Dict[str, Optional[RetryPolicy]] = {}
        self._sink: List[CloudEvent] = []  # internal event buffer (§5.2)
        self.event_log: List[CloudEvent] = []  # native event-sourcing log (§5.3)
        self.stats = WorkerStats()
        # The metrics plane (repro.obs): stage-boundary histograms recorded
        # at batch/slice granularity — see docs/ARCHITECTURE.md §7.  Default
        # on; ``metrics=False`` removes every recording from the hot loop.
        self._metrics = None
        if metrics:
            from ..obs.metrics import WorkerMetrics

            self._metrics = WorkerMetrics()
        # The trace plane: a Tracer makes fires open causal spans and stamps
        # produced events with (trace_id, span_id) extension attributes.
        self._tracer = tracer
        # (trace_id, span_id, span) of the fire currently running its
        # action — sink()/sink_batch() stamp it onto produced events.
        self._trace_ctx: Optional[tuple] = None
        self.finished = False
        self.result: Any = None
        self._stop = threading.Event()
        # Crash simulation (pool.crash_shard): a killed worker discards its
        # in-flight checkpoint/commit instead of completing it — the store
        # keeps its batch pending for redelivery to the next partition owner.
        self._killed = False
        # Why this worker left its runner ("stopped" | "finished" | "idle" |
        # "error"); None while scheduled.  The pool's reap() accounting reads
        # it — an idle-timeout departure is not a crash, whatever the lag is.
        self.exit_reason: Optional[str] = None
        self._dirty_triggers: set = set()
        # bumped on any trigger-structure change (add/intercept/enable):
        # the batch plane uses it to re-offer the rest of an in-flight slice
        # to triggers registered or enabled by an action mid-slice.
        self._struct_version = 0
        # triage pre-screen cache: whether any registered trigger could even
        # name-qualify for the vector join plane (recomputed per struct
        # version, so pure fire-run workloads skip the per-batch bucketing
        # pass entirely)
        self._joins_version = -1
        self._maybe_joins = True
        # while a slice evaluation is in flight: the slice index of the event
        # whose condition/action is currently running, so a dynamically
        # added/enabled trigger can record exactly where it came online
        self._slice_pos: Optional[int] = None
        self._birth_pos: Dict[str, int] = {}
        self.last_active = time.monotonic()

        self.batch_plane = batch_plane
        # The action plane (fire-run fast path): collapse a whole slice's
        # evaluate→fire loop into one fire-run condition call + one batched
        # action call.  Only effective on the batch plane.
        self.action_plane = action_plane
        self._vector_plane = None
        if batch_plane:
            mode = vector_join or os.environ.get("TRIGGERFLOW_JOIN_BACKEND", "auto")
            if mode != "off":
                try:
                    from .batch import VectorJoinPlane

                    self._vector_plane = VectorJoinPlane(backend=mode)
                except Exception:  # noqa: BLE001
                    if mode != "auto":
                        # an explicitly requested backend must fail loudly
                        raise
                    self._vector_plane = None  # auto: numpy missing, plane off

        self._recover()

    # -- recovery / registration -------------------------------------------------
    def _recover(self) -> None:
        """Reload trigger defs + last checkpointed contexts (restart path)."""
        specs = self.state_store.get_triggers(self.workflow)
        ckpt = self.state_store.get_contexts(self.workflow)
        for tid, spec in specs.items():
            trg = Trigger.from_dict(spec)
            if tid in ckpt:
                trg.context = ckpt[tid]
            self._index(trg)
        meta = self.state_store.get_workflow(self.workflow) or {}
        if meta.get("status") in ("succeeded", "failed"):
            self.finished = True
            self.result = meta.get("result")

    def _index(self, trg: Trigger) -> None:
        self.triggers[trg.trigger_id] = trg
        for subj in trg.activation_events:
            self._by_subject.setdefault(subj, []).append(trg)

    def _invalidate_dispatch(self) -> None:
        # Clear in place: run_once may hold a subject's entries across a
        # slice, and a dynamic trigger added mid-batch must be visible to the
        # next slice lookup.
        self._dispatch.clear()
        self._struct_version += 1

    def _mark_trigger_dirty(self, trigger_id: str) -> None:
        self._dirty_triggers.add(trigger_id)

    def add_trigger(self, trg: Trigger, persist: bool = True) -> str:
        with self.lock:
            self._index(trg)
            self._invalidate_dispatch()
            if self._slice_pos is not None:
                self._birth_pos[trg.trigger_id] = self._slice_pos
            if persist:
                self.state_store.put_trigger(self.workflow, trg.trigger_id, trg.to_dict())
        return trg.trigger_id

    def add_dynamic_trigger(self, trg: Trigger) -> str:
        tid = self.add_trigger(trg)
        self._mark_trigger_dirty(tid)
        return tid

    def set_trigger_enabled(self, trigger_id: str, enabled: bool) -> None:
        with self.lock:
            trg = self.triggers[trigger_id]
            trg.enabled = enabled
            self._mark_trigger_dirty(trigger_id)
            # entries read `enabled` live, so the dispatch cache stays valid,
            # but an in-flight slice must learn a trigger came (back) online
            self._struct_version += 1
            if enabled and self._slice_pos is not None:
                self._birth_pos[trigger_id] = self._slice_pos

    def intercept(self, trigger_id: str, interceptor_action: Dict[str, Any]) -> None:
        """Wrap a trigger's action with an interceptor (Def. 5)."""
        with self.lock:
            trg = self.triggers[trigger_id]
            trg.action = {"name": "intercepted", "interceptor": interceptor_action,
                          "inner": trg.action}
            self._invalidate_dispatch()
            self.state_store.put_trigger(self.workflow, trigger_id, trg.to_dict())

    def intercept_by_condition(self, condition_name: str, interceptor_action: Dict[str, Any]) -> int:
        n = 0
        with self.lock:
            for trg in self.triggers.values():
                if trg.condition.get("name") == condition_name:
                    self.intercept(trg.trigger_id, interceptor_action)
                    n += 1
        return n

    # -- context plumbing ---------------------------------------------------------
    def context_of(self, trigger_id: str) -> TriggerContext:
        ctx = self._contexts.get(trigger_id)
        if ctx is None:
            trg = self.triggers[trigger_id]
            ctx = TriggerContext(trg.context, self, trigger_id)
            self._contexts[trigger_id] = ctx
        return ctx

    def sink(self, event: CloudEvent) -> None:
        """Internal event production from condition/action code (§5.2)."""
        tc = self._trace_ctx
        if tc is not None:
            _trace_inject((event,), tc[0], tc[1])
            self._tracer.persist_open(tc[2])
        self._sink.append(event)
        m = self._metrics
        if m is None:
            self.event_store.publish(self.workflow, event)
        else:
            t0 = time.perf_counter()
            self.event_store.publish(self.workflow, event)
            m.publish.observe(time.perf_counter() - t0)

    def sink_batch(self, events: List[CloudEvent]) -> None:
        """Bulk ``sink``: one ``publish_batch`` (one append per partition,
        one commit-log write on durable stores) for a whole fire run."""
        if not events:
            return
        tc = self._trace_ctx
        if tc is not None:
            # downstream events link to the fire producing them; the open
            # span record is made durable *before* the children exist, so a
            # SIGKILL here can't orphan them (obs.trace module docs)
            _trace_inject(events, tc[0], tc[1])
            self._tracer.persist_open(tc[2])
        self._sink.extend(events)
        m = self._metrics
        if m is None:
            self.event_store.publish_batch(self.workflow, events)
        else:
            t0 = time.perf_counter()
            self.event_store.publish_batch(self.workflow, events)
            m.publish.observe_batch(len(events), time.perf_counter() - t0)

    def metrics_snapshot(self) -> Dict:
        """The worker's observability scrape: the registry snapshot with the
        ``WorkerStats`` counters folded in under their metric names — one
        export surface whether metrics recording is on or off."""
        from ..obs.metrics import empty_snapshot, fold_counters

        snap = (self._metrics.registry.snapshot()
                if self._metrics is not None else empty_snapshot())
        fold_counters(snap, {f"tf_{k}_total": v
                             for k, v in self.stats.snapshot().items()})
        return snap

    def set_result(self, value: Any) -> None:
        self.finished = True
        self.result = value
        meta = self.state_store.get_workflow(self.workflow) or {}
        meta.update({"status": (value or {}).get("status", "succeeded"), "result": value})
        self.state_store.put_workflow(self.workflow, meta)

    # -- partition-aware store access --------------------------------------------
    def _consume(self, max_events: int) -> List[CloudEvent]:
        if self.partitions is not None:
            return self.event_store.consume_partitions(
                self.workflow, self.partitions, max_events)
        return self.event_store.consume(self.workflow, max_events)

    def _commit(self, event_ids: List[str]) -> None:
        if self.partitions is not None:
            self.event_store.commit_partitions(
                self.workflow, self.partitions, event_ids)
        else:
            self.event_store.commit(self.workflow, event_ids)

    def _own_sink_events(self) -> List[CloudEvent]:
        """Sink events this worker may process inline.  ``sink()`` already
        published every event to the store; a partition-restricted worker must
        leave events routed to *another* shard's partition for their owner —
        processing them here would double-fire (the owner consumes them too)
        and this worker could never commit them anyway."""
        if self.partitions is None or self._partition_for is None:
            return self._sink
        own = set(self.partitions)
        part_for = self._partition_for
        return [e for e in self._sink if part_for(e.subject) in own]

    def _dlq_size(self) -> int:
        if self.partitions is not None:
            return self.event_store.dlq_size_partitions(
                self.workflow, self.partitions)
        return self.event_store.dlq_size(self.workflow)

    def _redrive(self, reasons=None) -> int:
        if self.partitions is not None:
            return self.event_store.redrive_partitions(
                self.workflow, self.partitions, reasons)
        return self.event_store.redrive(self.workflow, reasons)

    def _dlq_by_reason(self) -> Dict[str, int]:
        fn = getattr(self.event_store, "dlq_by_reason", None)
        return fn(self.workflow) if fn is not None else {}

    # -- the failure-policy plane (core.policy) -----------------------------------
    def _policy_of(self, trg: Trigger) -> Optional[RetryPolicy]:
        """Compiled RetryPolicy for the scalar-oracle path (the batch plane
        compiles it into ``_Entry``)."""
        tid = trg.trigger_id
        cache = self._policy_cache
        if tid not in cache:
            cache[tid] = (RetryPolicy.from_dict(trg.retry_policy)
                          if trg.retry_policy else None)
        return cache[tid]

    def _defer_filter(self, batch: List[CloudEvent]) -> List[CloudEvent]:
        """Drop events still inside their retry backoff window; deadlines
        that passed are released for this batch.  O(batch) only while
        retries are actually pending — the empty-map case is one falsy check
        in the callers."""
        ra = self._retry_after
        now = time.monotonic()
        kept: List[CloudEvent] = []
        for e in batch:
            t = ra.get(e.id)
            if t is None:
                kept.append(e)
            elif now >= t:
                del ra[e.id]
                kept.append(e)
        return kept

    def _policy_failure(self, ctx: TriggerContext, pol: RetryPolicy,
                        event: CloudEvent, kind: str) -> bool:
        """Record one failed condition/action run under a RetryPolicy.

        Bumps the durable attempt record in the trigger's context (it rides
        the next checkpoint, so the count survives SIGKILL and never resets
        on replay), then either schedules a backoff retry or — budget
        exhausted — quarantines the event with a structured ``poison:*``
        reason.  Either way the event is withheld from this batch's commit
        (``_no_commit``) and de-processed (``_seen``).  Returns True:
        callers must not treat the run as a fire."""
        stats = self.stats
        now = time.time()
        att = dict(ctx.get(RETRY_STATE_KEY) or {})
        rec = att.get(event.id)
        attempt = (rec[0] if rec else 0) + 1
        first = rec[1] if rec else now
        if kind == "timeout":
            stats.action_timeouts += 1
        if attempt >= pol.max_attempts:
            att.pop(event.id, None)
            ctx[RETRY_STATE_KEY] = att  # reassign: delta tracking sees it
            reason = {"timeout": REASON_TIMEOUT,
                      "condition": REASON_CONDITION_ERROR}.get(
                          kind, REASON_ACTION_ERROR)
            self.event_store.to_dlq(
                self.workflow,
                quarantined(event, reason, attempts=attempt,
                            first_failure=first, last_failure=now))
            stats.poison_events += 1
            if self._metrics is not None:
                self._metrics.registry.counter(
                    reason_counter_name(reason)).inc()
            if event.id not in self._dlq_counted:
                self._dlq_counted.add(event.id)
                stats.dlq_events += 1
            self._retry_after.pop(event.id, None)
        else:
            att[event.id] = [attempt, first, now]
            ctx[RETRY_STATE_KEY] = att
            stats.action_retries += 1
            self._retry_after[event.id] = (
                time.monotonic() + pol.backoff(attempt, event.id))
        self._seen.discard(event.id)
        self._no_commit.add(event.id)
        self._policy_dirty = True
        return True

    def _policy_success(self, ctx: TriggerContext, event: CloudEvent) -> None:
        """A retried event finally succeeded: drop its durable attempt
        record (bounds context growth) and its backoff timer."""
        att = ctx.get(RETRY_STATE_KEY)
        if att and event.id in att:
            att = dict(att)
            att.pop(event.id)
            ctx[RETRY_STATE_KEY] = att
            self._retry_after.pop(event.id, None)
            self._policy_dirty = True

    def _run_action_guarded(self, entry: "_Entry", event: CloudEvent) -> bool:
        """One scalar action attempt under the entry's policy (watchdog +
        retry/quarantine accounting).  Returns True when the run counts as a
        fire, False when it was deferred/quarantined by the policy."""
        pol = entry.policy
        try:
            if pol is not None and pol.action_timeout is not None:
                call_with_timeout(pol.action_timeout, entry.afn,
                                  entry.ctx, event, entry.aspec)
            else:
                entry.afn(entry.ctx, event, entry.aspec)
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            if pol is None:
                return True  # pre-policy semantics: a failed fire still fired
            kind = "timeout" if isinstance(exc, ActionTimeout) else "action"
            return not self._policy_failure(entry.ctx, pol, event, kind)
        if pol is not None:
            self._policy_success(entry.ctx, event)
        return True

    def _isolate_run(self, entry: "_Entry", fired: List[CloudEvent]) -> int:
        """Poison-slice isolation for the action plane: after a batched
        action failed under a policy, re-run the fire run per event so each
        one gets its own verdict (success / backoff / quarantine).  Safe
        because batched actions are contractually slice-isolating — they
        build their whole output before any side effect (actions.py docs) —
        so the failed call left no partial effects to double.  Returns the
        number of successful fires (the healthy remainder commits)."""
        ok = 0
        for event in fired:
            if self._run_action_guarded(entry, event):
                ok += 1
        return ok

    # -- the batch-plane hot loop --------------------------------------------------
    def _has_join_triggers(self) -> bool:
        """Cheap structural pre-screen for the vector join plane: does any
        trigger carry a condition the triage could claim at all?  Without
        one, the per-batch subject-bucketing pass is provably wasted."""
        if self._joins_version != self._struct_version:
            self._joins_version = self._struct_version
            self._maybe_joins = any(
                t.condition.get("name") in CLAIMABLE_CONDITIONS
                and not t.condition.get("exactly_once")
                for t in self.triggers.values())
        return self._maybe_joins

    def _entries_for(self, subject: str) -> List[_Entry]:
        entries = self._dispatch.get(subject)
        if entries is None:
            entries = [
                _Entry(trg, self.context_of(trg.trigger_id))
                for trg in self._by_subject.get(subject, ())
            ]
            self._dispatch[subject] = entries
        return entries

    def _eval_entry_slice(self, entry: _Entry, events: List[CloudEvent],
                          pos_base: int = 0) -> Tuple[int, bool, Optional[int]]:
        """Evaluate one trigger over an arrival-ordered, type-uniform slice.

        Implements the batched-condition protocol: the condition consumes a
        prefix and reports the first fire index (or None); the action runs
        with the firing event and evaluation resumes on the rest.  Returns
        ``(consumed_index_inclusive, fired_any, structure_changed_at)`` —
        consumption stops early only when a transient fire disables the
        trigger mid-slice; ``structure_changed_at`` is the earliest slice
        index at which condition/action code changed trigger structure
        (dynamic add, interception, enable/disable), so the caller can
        re-offer the tail to new candidates.  ``pos_base`` anchors
        ``self._slice_pos`` (the birth-position frame of the caller's slice)
        when ``events`` is itself a tail of that slice.
        """
        trg = entry.trg
        ctx = entry.ctx
        cspec = entry.cspec
        bfn = entry.bfn
        stats = self.stats
        fired_any = False
        changed_at: Optional[int] = None
        ver = self._struct_version
        pos = 0
        n = len(events)
        # The action plane: a fire-run condition reports *every* fire position
        # in one call and a batched action handles the whole run in one call —
        # the per-fire evaluate→act loop below collapses to two Python calls
        # per (trigger, slice).  Only for non-transient triggers (a transient
        # must stop at its first fire) whose action opted into batching (the
        # scalar per-fire path stays the oracle for invoke/intercepted/pyfunc
        # and any dynamic-structure choreography they perform).
        if (self.action_plane and entry.rfn is not None
                and entry.bafn is not None and not trg.transient):
            res = self._eval_entry_run(entry, events, pos_base)
            if res is not None:
                return res
        try:
            while pos < n:
                sl = events[pos:] if pos else events
                if bfn is not None:
                    # a structural change inside the batched call is anchored
                    # to the chunk start — the earliest (safe) re-offer point
                    self._slice_pos = pos_base + pos
                    try:
                        idx = bfn(ctx, sl, cspec)
                    except Exception:  # noqa: BLE001
                        # The failed call may have partially mutated the
                        # context, so re-sweeping the slice with the scalar
                        # fn would double-count.  Apply the scalar loop's
                        # exception semantics instead: condition error ⇒ no
                        # fire for the affected events.
                        traceback.print_exc()
                        stats.activations += n - pos
                        return n - 1, fired_any, changed_at
                    if self._struct_version != ver:
                        ver = self._struct_version
                        if changed_at is None:
                            changed_at = pos
                else:
                    idx = None
                    cfn = entry.cfn
                    for i, event in enumerate(sl):
                        self._slice_pos = pos_base + pos + i
                        try:
                            ok = cfn(ctx, event, cspec)
                        except Exception:  # noqa: BLE001
                            traceback.print_exc()
                            ok = False
                            if entry.policy is not None:
                                # condition error under a policy: retry the
                                # event later instead of committing it unfired
                                self._policy_failure(ctx, entry.policy,
                                                     event, "condition")
                        if self._struct_version != ver:
                            ver = self._struct_version
                            if changed_at is None:
                                changed_at = pos + i
                        if ok:
                            idx = i
                            break
                if idx is None:
                    stats.activations += n - pos
                    return n - 1, fired_any, changed_at
                stats.activations += idx + 1
                event = sl[idx]
                self._slice_pos = pos_base + pos + idx
                tracer = self._tracer
                span = None
                if tracer is not None:
                    span = tracer.fire_span(event, trg.trigger_id,
                                            self.workflow, 1)
                    if span is not None:
                        self._trace_ctx = (span["trace"], span["span"], span)
                try:
                    fired = self._run_action_guarded(entry, event)
                finally:
                    if span is not None:
                        tracer.end(span)
                        self._trace_ctx = None
                if self._struct_version != ver:
                    ver = self._struct_version
                    if changed_at is None:
                        changed_at = pos + idx
                pos += idx + 1
                if not fired:
                    # policy deferred/quarantined the attempt: no fire
                    # happened, so the trigger stays armed (a transient must
                    # still get its one real fire) and the slice continues —
                    # the healthy remainder commits, the event retries later
                    continue
                stats.fires += 1
                fired_any = True
                if trg.transient:
                    trg.enabled = False
                    self._mark_trigger_dirty(trg.trigger_id)
                    return pos - 1, fired_any, changed_at
                if not trg.enabled:
                    # the action disabled its own trigger: stop consuming, as
                    # the scalar oracle (which re-checks enabled per event)
                    # would — the tail re-enters candidate resolution
                    return pos - 1, fired_any, changed_at
            return n - 1, fired_any, changed_at
        finally:
            self._slice_pos = None

    def _eval_entry_run(self, entry: _Entry, events: List[CloudEvent],
                        pos_base: int = 0) -> Optional[Tuple[int, bool, Optional[int]]]:
        """The action-plane fast path: one fire-run condition call + one
        batched action call for the whole slice.  Returns ``None`` when the
        condition declines the run (dedup, timeouts, anything needing
        per-event care) — the caller then falls through to the per-fire
        protocol.  Structure changes made by the batched action are anchored
        at the run's first fire (the earliest event whose action could have
        caused them) for the caller's re-offer pass."""
        trg = entry.trg
        ctx = entry.ctx
        stats = self.stats
        n = len(events)
        ver = self._struct_version
        self._slice_pos = pos_base
        try:
            try:
                fires = entry.rfn(ctx, events, entry.cspec)
            except Exception:  # noqa: BLE001
                # same contract as a failed batched-condition call: the run
                # may have partially mutated the context, so re-sweeping
                # would double-count — condition error ⇒ no fire.
                traceback.print_exc()
                stats.activations += n
                return n - 1, False, (0 if self._struct_version != ver else None)
            if fires is None:
                return None
            changed_at: Optional[int] = 0 if self._struct_version != ver else None
            ver = self._struct_version
            stats.activations += n
            if not fires:
                return n - 1, False, changed_at
            fired = events if len(fires) == n else [events[i] for i in fires]
            self._slice_pos = pos_base + fires[0]
            tracer = self._tracer
            span = None
            if tracer is not None:
                span = tracer.fire_span(fired[0], trg.trigger_id,
                                        self.workflow, len(fires))
                if span is not None:
                    self._trace_ctx = (span["trace"], span["span"], span)
            m = self._metrics
            t_fire = time.perf_counter() if m is not None else 0.0
            n_fired = len(fires)
            try:
                entry.bafn(ctx, fired, entry.aspec)
            except Exception:  # noqa: BLE001
                traceback.print_exc()
                if entry.policy is not None:
                    # poison-slice isolation: re-run per event so the poison
                    # event alone is deferred/quarantined and the healthy
                    # remainder of the run commits (PR-3 slice pattern)
                    n_fired = self._isolate_run(entry, fired)
            else:
                if entry.policy is not None and ctx.get(RETRY_STATE_KEY):
                    for event in fired:
                        self._policy_success(ctx, event)
            finally:
                if m is not None:
                    m.fire.observe_batch(len(fires), time.perf_counter() - t_fire)
                if span is not None:
                    tracer.end(span)
                    self._trace_ctx = None
            if self._struct_version != ver and changed_at is None:
                changed_at = fires[0]
            stats.fires += n_fired
            return n - 1, n_fired > 0, changed_at
        finally:
            self._slice_pos = None

    def _process_group(self, subject: str, etype: str, events: List[CloudEvent],
                       processed_ids: List[str]) -> bool:
        """Activate matching triggers over one (subject, type) slice."""
        stats = self.stats
        fired_any = False
        pos = 0
        n = len(events)
        while pos < n:
            # Re-fetched per sub-run so mid-slice structural changes (dynamic
            # triggers, interception) are visible after a transient fire.
            entries = self._entries_for(subject)
            if not entries:
                # Unknown subject: drop (but count).  Nothing to wait for, so
                # the events are committed, exactly like the scalar path.
                # Counting goes through ``_dlq_counted`` like the quarantine
                # branch below: one increment per dropped event, however many
                # deliveries it takes to commit (at-least-once redelivery
                # under on_fire must not re-count).
                counted = self._dlq_counted
                for e in events[pos:]:
                    if e.id not in counted:
                        counted.add(e.id)
                        stats.dlq_events += 1
                processed_ids.extend(e.id for e in events[pos:])
                return fired_any
            sl = events[pos:] if pos else events
            cover = -1
            change_min: Optional[int] = None
            any_enabled = False
            evaluated = set()
            self._birth_pos.clear()  # birth positions are sl-frame relative
            for entry in entries:
                if not entry.matches(etype):
                    continue
                any_enabled = True
                evaluated.add(entry.trg.trigger_id)
                consumed, fired, changed_at = self._eval_entry_slice(entry, sl)
                if fired:
                    fired_any = True
                if consumed > cover:
                    cover = consumed
                if changed_at is not None and (
                        change_min is None or changed_at < change_min):
                    change_min = changed_at
            if not any_enabled:
                # All candidate triggers disabled → out-of-order → DLQ (§3.4),
                # tagged ``disabled`` so reason-filtered redrives can pick it
                # back up without touching poison quarantines.
                to_dlq = self.event_store.to_dlq
                seen_discard = self._seen.discard
                counted = self._dlq_counted
                for e in sl:
                    to_dlq(self.workflow, quarantined(e, REASON_DISABLED))
                    seen_discard(e.id)
                    if e.id not in counted:
                        counted.add(e.id)
                        stats.dlq_events += 1
                return fired_any
            if change_min is not None:
                # An action (or condition) changed trigger structure at slice
                # index ``change_min``: triggers registered or enabled there
                # must still see the rest of this sub-run's coverage — the
                # scalar loop re-resolves candidates per event (events beyond
                # ``cover`` re-enter the outer loop and see them naturally).
                if self._reoffer_tail(subject, etype, sl, change_min, cover,
                                      evaluated):
                    fired_any = True
            if cover == len(sl) - 1:  # common case: whole slice covered
                processed_ids.extend(e.id for e in sl)
            else:
                processed_ids.extend(e.id for e in sl[:cover + 1])
            pos += cover + 1
        return fired_any

    def _reoffer_tail(self, subject: str, etype: str, sl: List[CloudEvent],
                      change_min: int, cover: int, evaluated: set) -> bool:
        """Deliver the slice tail to candidates that appeared (or came
        online) mid-slice and were not part of the original sweep.  Each
        fresh trigger starts at its recorded *birth position* (the event
        whose condition/action brought it online — inclusive, matching the
        scalar oracle, whose live match-list iteration visits a just-added
        trigger for that very event), falling back to the sweep's earliest
        change point.  Loops because a re-offered trigger's action can add
        further triggers; terminates since every round consumes trigger ids
        into ``evaluated`` and a round without fresh candidates stops."""
        fired_any = False
        births = self._birth_pos
        while change_min <= cover:
            fresh = [
                entry for entry in self._entries_for(subject)
                if entry.trg.trigger_id not in evaluated and entry.matches(etype)
            ]
            if not fresh:
                break
            next_change: Optional[int] = None
            for entry in fresh:
                tid = entry.trg.trigger_id
                evaluated.add(tid)
                start = births.get(tid, change_min)
                if start > cover:
                    continue
                tail = sl[start:cover + 1]
                _consumed, fired, changed_at = self._eval_entry_slice(
                    entry, tail, pos_base=start)
                if fired:
                    fired_any = True
                if changed_at is not None:
                    abs_change = start + changed_at
                    if next_change is None or abs_change < next_change:
                        next_change = abs_change
            if next_change is None:
                break
            change_min = next_change
        return fired_any

    def run_once(self, max_events: Optional[int] = None) -> int:
        """Process one batch.  Returns number of events processed."""
        if not self.batch_plane:
            return self._run_once_scalar(max_events)
        with self.lock:
            batch = self._consume(max_events or self.batch_size)
            if self._retry_after and batch:
                # events inside their retry backoff window stay pending in
                # the store instead of hot-redelivering into the pipeline
                batch = self._defer_filter(batch)
            if not batch and not self._sink:
                return 0
            m = self._metrics
            if m is not None and batch:
                # publish→consume lag at batch granularity: the oldest
                # event's publish stamp bounds every event in the batch
                t_pub = batch[0].time
                if t_pub is not None:
                    m.consume_lag.observe_batch(
                        len(batch), max(0.0, time.time() - t_pub) * len(batch))
            # Stores that only ever hand out uncommitted events
            # (``UNCOMMITTED_ONLY``) make the per-event committed round-trip a
            # provable no-op; in-flight dedup against ``_seen`` suffices.
            check_committed = not getattr(
                self.event_store, "UNCOMMITTED_ONLY", False)
            workflow = self.workflow
            is_committed = self.event_store.is_committed if check_committed else None
            seen = self._seen
            seen_add = seen.add
            event_log = self.event_log if self.keep_event_log else None
            stats = self.stats
            vector_plane = self._vector_plane
            processed_ids: List[str] = []
            fired_any = False
            n_new = 0
            # Tier 1 — vectorized triage: when nothing needs per-event care
            # (no in-flight ids, store redelivers only uncommitted events, no
            # event-sourcing log), the pure-counting share of the batch is
            # folded into one segmented-sum array op and only the leftover
            # events enter the Python path.
            if (vector_plane is not None and not seen and is_committed is None
                    and event_log is None and not self._sink and len(batch) > 1
                    and self._has_join_triggers()):
                t_join = time.perf_counter() if m is not None else 0.0
                try:
                    res = vector_plane.triage(batch, self._entries_for, stats)
                except Exception:  # noqa: BLE001
                    # e.g. a non-numeric ctx["expected"] set via introspection:
                    # screening raises before any context is mutated, so the
                    # exact path can safely take the whole batch (the scalar
                    # loop contains the same error per event).
                    traceback.print_exc()
                    res = None
                if res is not None:
                    handled_ids, batch = res
                    if m is not None and handled_ids:
                        m.join_kernel.observe_batch(
                            len(handled_ids), time.perf_counter() - t_join)
                    n_new += len(handled_ids)
                    processed_ids.extend(handled_ids)
                    # protect the uncommitted window: even under every_batch
                    # the checkpoint/commit can fail, and a retry must not
                    # re-count the redelivered events (their counters already
                    # advanced)
                    seen.update(handled_ids)
            queue = batch
            qi = 0
            t_eval = time.perf_counter() if m is not None else 0.0
            while qi < len(queue):
                # Group the segment into type-uniform *runs* per subject:
                # consecutive same-type events of one subject share a slice,
                # and a type change (e.g. a timeout between result events)
                # starts a new group — so same-subject arrival order is fully
                # preserved across types (the bus's per-key guarantee).
                groups: List[Tuple[str, str, List[CloudEvent]]] = []
                current: Dict[str, List] = {}  # subject -> [type, events]
                while qi < len(queue):
                    event = queue[qi]
                    qi += 1
                    eid = event.id
                    if eid in seen or (
                        is_committed is not None and is_committed(workflow, eid)
                    ):
                        continue  # at-least-once dedup (§3.4)
                    seen_add(eid)
                    if event_log is not None:
                        event_log.append(event)
                    n_new += 1
                    subject = event.subject
                    cur = current.get(subject)
                    if cur is not None and cur[0] == event.type:
                        cur[1].append(event)
                    else:
                        evs = [event]
                        current[subject] = [event.type, evs]
                        groups.append((subject, event.type, evs))
                for subject, etype, evs in groups:
                    if self._process_group(subject, etype, evs, processed_ids):
                        fired_any = True
                    # Drain internally-produced events in the same batch (§5.2).
                    if self._sink:
                        queue.extend(self._own_sink_events())
                        self._sink.clear()
            stats.events_processed += n_new
            stats.batches += 1
            if m is not None and n_new:
                m.batch_eval.observe_batch(n_new, time.perf_counter() - t_eval)
            if self._no_commit:
                # deferred/quarantined mid-slice: withheld from this commit
                # (a quarantined id that committed would poison its redrive)
                nc = self._no_commit
                processed_ids = [i for i in processed_ids if i not in nc]
                nc.clear()
            if processed_ids:
                self.last_active = time.monotonic()
            # Checkpoint: contexts first, then commit (§3.4 ordering).  Retry
            # bookkeeping (durable attempt counters) must reach the
            # checkpoint even when nothing fired, or a SIGKILL between
            # attempts would reset the budget.
            if (fired_any or self._policy_dirty
                    or (self.commit_policy == "every_batch" and processed_ids)):
                if m is None:
                    self._checkpoint(processed_ids)
                else:
                    t_ck = time.perf_counter()
                    self._checkpoint(processed_ids)
                    m.checkpoint.observe(time.perf_counter() - t_ck)
                self._policy_dirty = False
                if fired_any and self._dlq_size():
                    # fire progress may unblock out-of-order sequences:
                    # redrive the ``disabled`` class only — poison stays put
                    self._redrive(AUTO_REDRIVE_REASONS)
            return len(processed_ids)

    # -- the legacy per-event interpreter (parity oracle) --------------------------
    def _process_one(self, event: CloudEvent) -> bool:
        """Activate matching triggers for one event.  Returns True if any fired."""
        fired = False
        matches = self._by_subject.get(event.subject)
        if not matches:
            # Unknown subject: drop (but count). Sequenced-but-disabled triggers
            # are handled below; a totally unknown event has nothing to wait
            # for.  Guarded by ``_dlq_counted`` exactly like the batch plane's
            # unknown-subject branch and the quarantine path: one increment
            # per dropped event across redeliveries, never one per delivery.
            if event.id not in self._dlq_counted:
                self._dlq_counted.add(event.id)
                self.stats.dlq_events += 1
            return False
        any_enabled = False
        for trg in matches:
            if not trg.enabled:
                continue
            if trg.event_type and trg.event_type != event.type:
                continue
            any_enabled = True
            ctx = self.context_of(trg.trigger_id)
            pol = self._policy_of(trg)
            self.stats.activations += 1
            try:
                ok = run_condition(trg.condition, ctx, event)
            except Exception:  # noqa: BLE001
                traceback.print_exc()
                ok = False
                if pol is not None:
                    self._policy_failure(ctx, pol, event, "condition")
            if ok:
                tracer = self._tracer
                span = None
                if tracer is not None:
                    span = tracer.fire_span(event, trg.trigger_id,
                                            self.workflow, 1)
                    if span is not None:
                        self._trace_ctx = (span["trace"], span["span"], span)
                ran = True
                try:
                    if pol is not None and pol.action_timeout is not None:
                        call_with_timeout(pol.action_timeout, run_action,
                                          trg.action, ctx, event)
                    else:
                        run_action(trg.action, ctx, event)
                except Exception as exc:  # noqa: BLE001
                    traceback.print_exc()
                    if pol is not None:
                        kind = ("timeout" if isinstance(exc, ActionTimeout)
                                else "action")
                        ran = not self._policy_failure(ctx, pol, event, kind)
                else:
                    if pol is not None:
                        self._policy_success(ctx, event)
                finally:
                    if span is not None:
                        tracer.end(span)
                        self._trace_ctx = None
                if not ran:
                    continue  # deferred/quarantined: not a fire, stay armed
                self.stats.fires += 1
                fired = True
                if trg.transient:
                    trg.enabled = False
                    self._mark_trigger_dirty(trg.trigger_id)
        if not any_enabled:
            # All candidate triggers disabled → out-of-order event → DLQ (§3.4).
            self.event_store.to_dlq(self.workflow,
                                    quarantined(event, REASON_DISABLED))
            self._seen.discard(event.id)
            if event.id not in self._dlq_counted:
                self._dlq_counted.add(event.id)
                self.stats.dlq_events += 1
            return False
        return fired

    def _run_once_scalar(self, max_events: Optional[int] = None) -> int:
        """The pre-batch-plane per-event loop (``batch_plane=False``)."""
        with self.lock:
            batch = self._consume(max_events or self.batch_size)
            if self._retry_after and batch:
                batch = self._defer_filter(batch)
            if not batch and not self._sink:
                return 0
            m = self._metrics
            if m is not None and batch:
                t_pub = batch[0].time
                if t_pub is not None:
                    m.consume_lag.observe_batch(
                        len(batch), max(0.0, time.time() - t_pub) * len(batch))
            t_eval = time.perf_counter() if m is not None else 0.0
            # Same predicate as the batch plane: on an UNCOMMITTED_ONLY store
            # the per-event is_committed round-trip can never return True —
            # for partitioned *and* whole-stream consumers alike — so dedup
            # against the in-flight set alone suffices.
            check_committed = not getattr(
                self.event_store, "UNCOMMITTED_ONLY", False)
            processed_ids: List[str] = []
            fired_any = False
            queue = list(batch)
            i = 0
            while i < len(queue):
                event = queue[i]
                i += 1
                if event.id in self._seen or (
                    check_committed
                    and self.event_store.is_committed(self.workflow, event.id)
                ):
                    continue  # at-least-once dedup (§3.4)
                self._seen.add(event.id)
                if self.keep_event_log:
                    self.event_log.append(event)
                self.stats.events_processed += 1
                if self._process_one(event):
                    fired_any = True
                if event.id in self._seen:  # not DLQ'd
                    processed_ids.append(event.id)
                # Drain internally-produced events in the same batch (§5.2).
                if self._sink:
                    queue.extend(self._own_sink_events())
                    self._sink.clear()
            self.stats.batches += 1
            if m is not None and processed_ids:
                m.batch_eval.observe_batch(
                    len(processed_ids), time.perf_counter() - t_eval)
            if self._no_commit:
                nc = self._no_commit
                processed_ids = [i for i in processed_ids if i not in nc]
                nc.clear()
            if processed_ids:
                self.last_active = time.monotonic()
            # Checkpoint: contexts first, then commit (§3.4 ordering); see
            # run_once — attempt counters checkpoint even without fires.
            if (fired_any or self._policy_dirty
                    or (self.commit_policy == "every_batch" and processed_ids)):
                if m is None:
                    self._checkpoint(processed_ids)
                else:
                    t_ck = time.perf_counter()
                    self._checkpoint(processed_ids)
                    m.checkpoint.observe(time.perf_counter() - t_ck)
                self._policy_dirty = False
                if fired_any and self._dlq_size():
                    self._redrive(AUTO_REDRIVE_REASONS)
            return len(processed_ids)

    def _checkpoint(self, processed_ids: List[str]) -> None:
        """Persist what changed — context deltas and dirty trigger ids only —
        then commit the batch (§3.4 ordering)."""
        if self._killed:
            # Crashed mid-batch (crash_shard): discard — nothing is persisted
            # and nothing commits, so the whole batch stays pending in the
            # store and is redelivered to the partitions' next owner.
            return
        deltas = {}
        dirty_ctxs = []
        for tid, ctx in self._contexts.items():
            if ctx.dirty:
                deltas[tid] = ctx.build_delta()
                dirty_ctxs.append(ctx)
        if deltas:
            # a store failure raises here with dirty tracking intact, so the
            # deltas are re-emitted on the next checkpoint attempt
            self.state_store.put_contexts_delta(self.workflow, deltas)
            for ctx in dirty_ctxs:
                ctx.mark_checkpointed()
        if self._dirty_triggers:
            specs = {
                tid: self.triggers[tid].to_dict()
                for tid in self._dirty_triggers
                if tid in self.triggers
            }
            if specs:
                self.state_store.put_triggers(self.workflow, specs)
            self._dirty_triggers.clear()
        self._commit(processed_ids)
        if self._tracer is not None:
            # span durability rides the checkpoint: a batch's fire spans hit
            # the segment sink with the same cadence as its effects
            self._tracer.flush()
        self._seen.difference_update(processed_ids)
        if self._dlq_counted:
            # a once-quarantined event that finally committed leaves the DLQ
            # lifecycle: a *future* quarantine is a new one and counts again
            self._dlq_counted.difference_update(processed_ids)

    def failure_diagnostics(self) -> str:
        """One-line stuck-workflow triage: lag, DLQ depth by reason, pending
        retry backoffs — so a CI timeout traceback is debuggable alone."""
        try:
            lag = self.event_store.lag(self.workflow)
        except Exception:  # noqa: BLE001 - diagnostics never mask the timeout
            lag = "?"
        try:
            dlq = self._dlq_by_reason() or self._dlq_size()
        except Exception:  # noqa: BLE001
            dlq = "?"
        return (f"lag={lag} dlq={dlq} deferred_retries={len(self._retry_after)} "
                f"uncommitted_inflight={len(self._seen)}")

    # -- loops ------------------------------------------------------------------------
    def run_until_complete(self, timeout: float = 60.0, poll: float = 0.001) -> Any:
        """Drive the worker until the workflow ends (deterministic mode)."""
        deadline = time.monotonic() + timeout
        while not self.finished:
            n = self.run_once()
            if n == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"workflow {self.workflow} did not finish: "
                        + self.failure_diagnostics())
                time.sleep(poll)
        return self.result

    def run_forever(self, poll: float = 0.002, idle_timeout: Optional[float] = None) -> None:
        """Threaded mode; exits on stop(), workflow end, or idle_timeout
        (the latter is how KEDA-style scale-to-zero reclaims the worker).
        Every exit path records ``exit_reason`` ("stopped" | "finished" |
        "idle" | "error"), so a reaper can classify the departure without
        peeking at private state — see ``stopped`` / ``crashed``."""
        self.exit_reason = None
        try:
            while not self._stop.is_set() and not self.finished:
                n = self.run_once()
                if n == 0:
                    if idle_timeout is not None and time.monotonic() - self.last_active > idle_timeout:
                        self.exit_reason = "idle"
                        return
                    time.sleep(poll)
            self.exit_reason = "finished" if self.finished else "stopped"
        except BaseException:
            self.exit_reason = "error"
            raise

    def stop(self) -> None:
        self._stop.set()

    @property
    def stopped(self) -> bool:
        """True once a stop (or kill) was requested — the public face of the
        stop flag, for reapers deciding whether a dead loop was asked to
        die."""
        return self._stop.is_set()

    @property
    def crashed(self) -> bool:
        """Did this worker's loop die *unexpectedly*?  Only meaningful after
        the loop exited: a recorded ``error``, or no recorded reason at all
        on a worker that finished nothing and was never told to stop (a
        thread that died mid-flight).  Idle/stop/finish departures — whatever
        the lag at reap time — are clean scale-downs, not crashes."""
        return not self.finished and (
            self.exit_reason == "error"
            or (self.exit_reason is None and not self._stop.is_set()))

    def kill(self) -> None:
        """Simulate a crash: stop consuming AND discard any in-flight
        checkpoint/commit (``_checkpoint`` becomes a no-op).  In-memory
        context mutations die with the worker object; events it processed
        but never committed stay pending in the store — exactly the state a
        SIGKILLed process leaves behind (§3.4 recovery replays them over the
        last durable checkpoint)."""
        self._killed = True
        self._stop.set()
