"""TF-Worker: the per-workflow event processor (paper §4).

Processing pipeline per batch (§3.2 trigger life-cycle + §3.4 fault tolerance):

  consume → dedup by event id → match triggers by subject (+type) →
  **activate** (evaluate Condition over the shared Context) →
  **fire** (run Action; transient triggers deactivate) →
  checkpoint: persist dirty contexts → commit processed events → redrive DLQ.

Crash-consistency contract: contexts are persisted *before* events are
committed, so after a crash the event broker re-delivers uncommitted events
and replaying them over the last checkpointed contexts reconstructs the state
(conditions are idempotent; the built-in aggregators can additionally dedup by
event id inside their context for exactly-once counting across the
persist/commit window).

Out-of-order sequences: an event whose trigger exists but is *disabled* goes
to the Dead Letter Queue and is redriven when any trigger state changes
(exactly the A→B example in §3.4).
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Dict, Iterable, List, Optional

from .actions import run_action, run_condition
from .context import TriggerContext
from .events import TYPE_INIT, CloudEvent
from .eventstore import EventStore
from .functions import FunctionBackend
from .statestore import StateStore
from .triggers import Trigger


class WorkerStats:
    __slots__ = ("events_processed", "activations", "fires", "batches", "dlq_events")

    def __init__(self) -> None:
        self.events_processed = 0
        self.activations = 0
        self.fires = 0
        self.batches = 0
        self.dlq_events = 0


class TFWorker:
    def __init__(
        self,
        workflow: str,
        event_store: EventStore,
        state_store: StateStore,
        backend: FunctionBackend,
        batch_size: int = 512,
        commit_policy: str = "on_fire",  # "on_fire" (paper) | "every_batch"
        keep_event_log: bool = True,
        timers=None,
        partitions: Optional[Iterable[int]] = None,
    ) -> None:
        self.workflow = workflow
        self.event_store = event_store
        self.state_store = state_store
        self.backend = backend
        self.timers = timers
        self.batch_size = batch_size
        self.commit_policy = commit_policy
        self.keep_event_log = keep_event_log
        # Assigned partition subset (consumer-group shard mode).  None means
        # "the whole stream" (the classic single-worker deployment).  A shard
        # *owns* its partitions exclusively, so consume() never races another
        # consumer of the same events and per-event is_committed checks are
        # unnecessary when the store only hands out uncommitted events.
        self.partitions: Optional[tuple] = (
            tuple(partitions) if partitions is not None else None
        )

        self.lock = threading.RLock()
        self.triggers: Dict[str, Trigger] = {}
        self._by_subject: Dict[str, List[Trigger]] = {}
        self._contexts: Dict[str, TriggerContext] = {}
        self._seen: set = set()          # processed-but-uncommitted event ids
        self._sink: List[CloudEvent] = []  # internal event buffer (§5.2)
        self.event_log: List[CloudEvent] = []  # native event-sourcing log (§5.3)
        self.stats = WorkerStats()
        self.finished = False
        self.result: Any = None
        self._stop = threading.Event()
        self._trigger_state_dirty = False
        self.last_active = time.monotonic()

        self._recover()

    # -- recovery / registration -------------------------------------------------
    def _recover(self) -> None:
        """Reload trigger defs + last checkpointed contexts (restart path)."""
        specs = self.state_store.get_triggers(self.workflow)
        ckpt = self.state_store.get_contexts(self.workflow)
        for tid, spec in specs.items():
            trg = Trigger.from_dict(spec)
            if tid in ckpt:
                trg.context = ckpt[tid]
            self._index(trg)
        meta = self.state_store.get_workflow(self.workflow) or {}
        if meta.get("status") in ("succeeded", "failed"):
            self.finished = True
            self.result = meta.get("result")

    def _index(self, trg: Trigger) -> None:
        self.triggers[trg.trigger_id] = trg
        for subj in trg.activation_events:
            self._by_subject.setdefault(subj, []).append(trg)

    def add_trigger(self, trg: Trigger, persist: bool = True) -> str:
        with self.lock:
            self._index(trg)
            if persist:
                self.state_store.put_trigger(self.workflow, trg.trigger_id, trg.to_dict())
        return trg.trigger_id

    def add_dynamic_trigger(self, trg: Trigger) -> str:
        tid = self.add_trigger(trg)
        self._trigger_state_dirty = True
        return tid

    def set_trigger_enabled(self, trigger_id: str, enabled: bool) -> None:
        with self.lock:
            trg = self.triggers[trigger_id]
            trg.enabled = enabled
            self._trigger_state_dirty = True

    def intercept(self, trigger_id: str, interceptor_action: Dict[str, Any]) -> None:
        """Wrap a trigger's action with an interceptor (Def. 5)."""
        with self.lock:
            trg = self.triggers[trigger_id]
            trg.action = {"name": "intercepted", "interceptor": interceptor_action,
                          "inner": trg.action}
            self.state_store.put_trigger(self.workflow, trigger_id, trg.to_dict())

    def intercept_by_condition(self, condition_name: str, interceptor_action: Dict[str, Any]) -> int:
        n = 0
        with self.lock:
            for trg in self.triggers.values():
                if trg.condition.get("name") == condition_name:
                    self.intercept(trg.trigger_id, interceptor_action)
                    n += 1
        return n

    # -- context plumbing ---------------------------------------------------------
    def context_of(self, trigger_id: str) -> TriggerContext:
        ctx = self._contexts.get(trigger_id)
        if ctx is None:
            trg = self.triggers[trigger_id]
            ctx = TriggerContext(trg.context, self, trigger_id)
            self._contexts[trigger_id] = ctx
        return ctx

    def sink(self, event: CloudEvent) -> None:
        """Internal event production from condition/action code (§5.2)."""
        self._sink.append(event)
        self.event_store.publish(self.workflow, event)

    def set_result(self, value: Any) -> None:
        self.finished = True
        self.result = value
        meta = self.state_store.get_workflow(self.workflow) or {}
        meta.update({"status": (value or {}).get("status", "succeeded"), "result": value})
        self.state_store.put_workflow(self.workflow, meta)

    # -- partition-aware store access --------------------------------------------
    def _consume(self, max_events: int) -> List[CloudEvent]:
        if self.partitions is not None:
            return self.event_store.consume_partitions(
                self.workflow, self.partitions, max_events)
        return self.event_store.consume(self.workflow, max_events)

    def _commit(self, event_ids: List[str]) -> None:
        if self.partitions is not None:
            self.event_store.commit_partitions(
                self.workflow, self.partitions, event_ids)
        else:
            self.event_store.commit(self.workflow, event_ids)

    def _own_sink_events(self) -> List[CloudEvent]:
        """Sink events this worker may process inline.  ``sink()`` already
        published every event to the store; a partition-restricted worker must
        leave events routed to *another* shard's partition for their owner —
        processing them here would double-fire (the owner consumes them too)
        and this worker could never commit them anyway."""
        if self.partitions is None:
            return self._sink
        part_for = getattr(self.event_store, "partition_for", None)
        if part_for is None:
            return self._sink
        own = set(self.partitions)
        return [e for e in self._sink if part_for(e.subject) in own]

    def _dlq_size(self) -> int:
        if self.partitions is not None:
            return self.event_store.dlq_size_partitions(
                self.workflow, self.partitions)
        return self.event_store.dlq_size(self.workflow)

    def _redrive(self) -> int:
        if self.partitions is not None:
            return self.event_store.redrive_partitions(
                self.workflow, self.partitions)
        return self.event_store.redrive(self.workflow)

    # -- the hot loop ---------------------------------------------------------------
    def _process_one(self, event: CloudEvent) -> bool:
        """Activate matching triggers for one event.  Returns True if any fired."""
        fired = False
        matches = self._by_subject.get(event.subject)
        if not matches:
            # Unknown subject: drop (but count). Sequenced-but-disabled triggers
            # are handled below; a totally unknown event has nothing to wait for.
            self.stats.dlq_events += 1
            return False
        any_enabled = False
        for trg in matches:
            if not trg.enabled:
                continue
            if trg.event_type and trg.event_type != event.type:
                continue
            any_enabled = True
            ctx = self.context_of(trg.trigger_id)
            self.stats.activations += 1
            try:
                ok = run_condition(trg.condition, ctx, event)
            except Exception:  # noqa: BLE001
                traceback.print_exc()
                ok = False
            if ok:
                try:
                    run_action(trg.action, ctx, event)
                except Exception:  # noqa: BLE001
                    traceback.print_exc()
                self.stats.fires += 1
                fired = True
                if trg.transient:
                    trg.enabled = False
                    self._trigger_state_dirty = True
        if not any_enabled:
            # All candidate triggers disabled → out-of-order event → DLQ (§3.4).
            self.event_store.to_dlq(self.workflow, event)
            self._seen.discard(event.id)
            self.stats.dlq_events += 1
            return False
        return fired

    def run_once(self, max_events: Optional[int] = None) -> int:
        """Process one batch.  Returns number of events processed."""
        with self.lock:
            batch = self._consume(max_events or self.batch_size)
            if not batch and not self._sink:
                return 0
            # Exclusive partition owners skip the per-event committed check:
            # the group guarantees no other consumer commits their events, and
            # the store only hands out uncommitted ones.
            check_committed = self.partitions is None or not getattr(
                self.event_store, "UNCOMMITTED_ONLY", False)
            processed_ids: List[str] = []
            fired_any = False
            queue = list(batch)
            i = 0
            while i < len(queue):
                event = queue[i]
                i += 1
                if event.id in self._seen or (
                    check_committed
                    and self.event_store.is_committed(self.workflow, event.id)
                ):
                    continue  # at-least-once dedup (§3.4)
                self._seen.add(event.id)
                if self.keep_event_log:
                    self.event_log.append(event)
                self.stats.events_processed += 1
                if self._process_one(event):
                    fired_any = True
                if event.id in self._seen:  # not DLQ'd
                    processed_ids.append(event.id)
                # Drain internally-produced events in the same batch (§5.2).
                if self._sink:
                    queue.extend(self._own_sink_events())
                    self._sink.clear()
            self.stats.batches += 1
            if processed_ids:
                self.last_active = time.monotonic()
            # Checkpoint: contexts first, then commit (§3.4 ordering).
            if fired_any or (self.commit_policy == "every_batch" and processed_ids):
                self._checkpoint(processed_ids)
                if fired_any and self._dlq_size():
                    n = self._redrive()
                    if n:
                        # redriven events must be reprocessable
                        pass
            return len(processed_ids)

    def _checkpoint(self, processed_ids: List[str]) -> None:
        dirty = {tid: dict(ctx) for tid, ctx in self._contexts.items() if ctx.dirty}
        if dirty:
            self.state_store.put_contexts(self.workflow, dirty)
            for ctx in self._contexts.values():
                ctx.dirty = False
        if self._trigger_state_dirty:
            for tid, trg in self.triggers.items():
                self.state_store.put_trigger(self.workflow, tid, trg.to_dict())
            self._trigger_state_dirty = False
        self._commit(processed_ids)
        for eid in processed_ids:
            self._seen.discard(eid)

    # -- loops ------------------------------------------------------------------------
    def run_until_complete(self, timeout: float = 60.0, poll: float = 0.001) -> Any:
        """Drive the worker until the workflow ends (deterministic mode)."""
        deadline = time.monotonic() + timeout
        while not self.finished:
            n = self.run_once()
            if n == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError(f"workflow {self.workflow} did not finish")
                time.sleep(poll)
        return self.result

    def run_forever(self, poll: float = 0.002, idle_timeout: Optional[float] = None) -> None:
        """Threaded mode; exits on stop(), workflow end, or idle_timeout
        (the latter is how KEDA-style scale-to-zero reclaims the worker)."""
        while not self._stop.is_set() and not self.finished:
            n = self.run_once()
            if n == 0:
                if idle_timeout is not None and time.monotonic() - self.last_active > idle_timeout:
                    return
                time.sleep(poll)

    def stop(self) -> None:
        self._stop.set()
