"""Triggerflow service facade (paper Fig. 1 API):

``create_workflow`` / ``add_trigger`` / ``add_event_source`` / ``get_state``
plus ``publish`` and worker lifecycle management.  The service wires together
the event store, the state store (database), the function backend, the timer
source and the controller/autoscaler.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional, Union

from .events import TYPE_INIT, CloudEvent
from .eventstore import EventStore, MemoryEventStore
from .functions import FunctionBackend, TimerSource
from .statestore import MemoryStateStore, StateStore
from .triggers import Trigger
from .worker import TFWorker


class Triggerflow:
    def __init__(
        self,
        event_store: Optional[EventStore] = None,
        state_store: Optional[StateStore] = None,
        backend: Optional[FunctionBackend] = None,
        inline_functions: bool = False,
        commit_policy: str = "on_fire",
        num_partitions: Optional[int] = None,
        num_shards: int = 1,
        pool=None,
    ) -> None:
        # A deployment-owned pool (e.g. repro.bus.ProcessShardPool) brings
        # its own stores: the facade and the autoscaler then drive *it*
        # instead of building a threaded pool — the ScalablePool protocol
        # (core.autoscaler) is the only contract between them.
        if pool is not None:
            event_store = event_store or pool.event_store
            state_store = state_store or pool.state_store
        if event_store is None and (num_partitions is not None or num_shards > 1):
            from ..bus import PartitionedEventStore

            event_store = PartitionedEventStore(num_partitions or max(2 * num_shards, 8))
        self.event_store = event_store or MemoryEventStore()
        self.state_store = state_store or MemoryStateStore()
        self.backend = backend or FunctionBackend(self.event_store, inline=inline_functions)
        self.timers = TimerSource(self.event_store)
        self.commit_policy = commit_policy
        self.num_shards = max(1, num_shards)
        self._workers: Dict[str, TFWorker] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._lock = threading.RLock()
        # Sharded runtime rides on any partition-capable store (repro.bus).
        self.pool = pool
        if pool is None and hasattr(self.event_store, "consume_partitions"):
            from ..bus import ShardedWorkerPool

            self.pool = ShardedWorkerPool(
                self.event_store,
                self.state_store,
                self.backend,
                timers=self.timers,
                commit_policy=self.commit_policy,
            )

    # -- Fig. 1 API -----------------------------------------------------------
    def create_workflow(self, workflow: str, meta: Optional[Dict[str, Any]] = None) -> None:
        self.event_store.create_stream(workflow)
        m = {"status": "created"}
        m.update(meta or {})
        self.state_store.put_workflow(workflow, m)

    def add_trigger(self, workflow: str, trigger: Union[Trigger, Iterable[Trigger]]) -> List[str]:
        triggers = [trigger] if isinstance(trigger, Trigger) else list(trigger)
        worker = self._workers.get(workflow)
        ids = []
        for trg in triggers:
            if self.pool is not None and self.pool.shard_count(workflow) > 0:
                ids.append(self.pool.add_trigger(workflow, trg))
            elif worker is not None:
                ids.append(worker.add_trigger(trg))
            else:
                self.state_store.put_trigger(workflow, trg.trigger_id, trg.to_dict())
                ids.append(trg.trigger_id)
        return ids

    def add_event_source(self, workflow: str, source) -> None:
        """Attach an external event source: anything with ``start(publish_fn)``."""
        source.start(lambda ev: self.event_store.publish(workflow, ev))

    def get_state(self, workflow: str) -> Optional[Dict[str, Any]]:
        return self.state_store.get_workflow(workflow)

    def get_trigger_context(self, workflow: str, trigger_id: str) -> Dict[str, Any]:
        if self.pool is not None and self.pool.shard_count(workflow) > 0:
            ctx = self.pool.trigger_context(workflow, trigger_id)
            if ctx:
                return ctx
        worker = self._workers.get(workflow)
        if worker is not None:
            return dict(worker.context_of(trigger_id))
        return self.state_store.get_contexts(workflow).get(trigger_id, {})

    # -- events ------------------------------------------------------------------
    def publish(self, workflow: str, event: CloudEvent) -> None:
        self.event_store.publish(workflow, event)

    def init_workflow(self, workflow: str, data: Any = None, subject: str = "$init") -> None:
        self.publish(workflow, CloudEvent(subject=subject, type=TYPE_INIT, data=data))

    def timeout(self, workflow: str, subject: str, delay: float) -> None:
        from .events import TYPE_TIMEOUT

        self.timers.after(workflow, delay, CloudEvent(subject=subject, type=TYPE_TIMEOUT))

    # -- interception (Def. 5) ------------------------------------------------------
    def intercept(
        self,
        workflow: str,
        interceptor_action: Dict[str, Any],
        trigger_id: Optional[str] = None,
        condition_name: Optional[str] = None,
    ) -> None:
        worker = self.worker(workflow)
        if trigger_id is not None:
            worker.intercept(trigger_id, interceptor_action)
        elif condition_name is not None:
            worker.intercept_by_condition(condition_name, interceptor_action)
        else:
            raise ValueError("need trigger_id or condition_name")

    # -- worker lifecycle -----------------------------------------------------------
    def start_shards(self, workflow: str, count: Optional[int] = None,
                     idle_timeout: Optional[float] = None) -> List[str]:
        """Run ``count`` worker shards (threads) for the workflow (repro.bus)."""
        if self.pool is None:
            raise RuntimeError("start_shards needs a partitioned event store "
                               "(construct Triggerflow with num_shards/num_partitions)")
        return self.pool.start_shards(workflow, count or self.num_shards,
                                      idle_timeout=idle_timeout)

    def worker(self, workflow: str) -> TFWorker:
        # Pool-backed mode: the workflow is served by shards; hand back the
        # first *in-process* one (they share trigger defs; contexts live with
        # the shard owning the subject's partition — see get_trigger_context).
        # Process pools have no in-process workers, so they fall through to a
        # classic facade worker (which must then only be used for read-side
        # APIs, never driven against live shard processes).
        if self.pool is not None and self.pool.shard_count(workflow) > 0:
            local = getattr(self.pool, "local_worker", None)
            if local is not None:
                w = local(workflow)
                if w is not None:
                    return w
        with self._lock:
            w = self._workers.get(workflow)
            if w is None:
                w = TFWorker(
                    workflow,
                    self.event_store,
                    self.state_store,
                    self.backend,
                    commit_policy=self.commit_policy,
                    timers=self.timers,
                )
                self._workers[workflow] = w
            return w

    def evict_worker(self, workflow: str) -> None:
        """Drop the in-memory worker (simulates a pod being reclaimed/crashed);
        a later ``worker()`` call reconstructs state from the stores."""
        with self._lock:
            w = self._workers.pop(workflow, None)
            if w is not None:
                w.stop()

    def start_worker(self, workflow: str, idle_timeout: Optional[float] = None) -> threading.Thread:
        w = self.worker(workflow)
        th = threading.Thread(
            target=w.run_forever, kwargs={"idle_timeout": idle_timeout},
            name=f"tf-worker-{workflow}", daemon=True,
        )
        with self._lock:
            self._threads[workflow] = th
        th.start()
        return th

    def worker_alive(self, workflow: str) -> bool:
        th = self._threads.get(workflow)
        return th is not None and th.is_alive()

    def run_until_complete(self, workflow: str, timeout: float = 60.0) -> Any:
        if self.pool is not None:
            if hasattr(self.pool, "drive"):
                if self.pool.shard_count(workflow) > 0:
                    return self.pool.drive(workflow, timeout=timeout)
            else:
                # A pool without drive (process pool) owns the stream even at
                # zero shards — an autoscaler (or a later start_shards) forks
                # the consumers.  Never drive a facade worker against it: a
                # second consumer on the shared bus double-fires (§3.4).
                self.pool.wait_drained(workflow, timeout=timeout)
                return self.pool.result(workflow)
        return self.worker(workflow).run_until_complete(timeout=timeout)

    def metrics_snapshot(self, workflow: Optional[str] = None) -> Dict[str, Any]:
        """One aggregated metrics snapshot for the whole deployment: every
        classic facade worker plus, when a shard pool serves the workflows,
        the pool's per-shard registries (thread pool merges in-process;
        process pool scrapes over the command pipe)."""
        from ..obs.metrics import empty_snapshot, merge_snapshot
        snap = empty_snapshot()
        with self._lock:
            workers = list(self._workers.values())
        for w in workers:
            if workflow is None or w.workflow == workflow:
                merge_snapshot(snap, w.metrics_snapshot())
        if self.pool is not None and hasattr(self.pool, "obs_snapshot"):
            wfs = [workflow] if workflow is not None \
                else self.event_store.workflows()
            for wf in wfs:
                merge_snapshot(snap, self.pool.obs_snapshot(wf))
        return snap

    def shutdown(self) -> None:
        if self.pool is not None:
            self.pool.stop_all()
        for w in self._workers.values():
            w.stop()
        for th in self._threads.values():
            th.join(timeout=2.0)
        self.timers.cancel_all()
        self.backend.shutdown()
