import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs on the production mesh and record memory analysis,
cost analysis and collective traffic (the §Dry-run / §Roofline data source).

The two lines above MUST run before any other import — jax locks the device
count on first initialization.

Usage:
    python -m repro.launch.dryrun --arch granite-20b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.distributed.hlo_analysis import collective_bytes, roofline_terms  # noqa: E402
from repro.distributed.sharding import Resolver, replicated, shardings_for  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import batch_specs, cache_specs  # noqa: E402
from repro.models import Model, unbox  # noqa: E402
from repro.models.layers import reset_activation_resolver, set_activation_resolver  # noqa: E402
from repro.training.optimizer import AdamW  # noqa: E402
from repro.training.train_step import (make_decode_step, make_prefill_step,  # noqa: E402
                                       make_train_step)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _batch_shardings(batch_spec, resolver: Resolver):
    out = {}
    for k, v in batch_spec.items():
        if k in ("patch_embeds", "patch_positions", "positions3") or v.ndim >= 1:
            axes = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = resolver.sharding(axes, v.shape)
        else:
            out[k] = replicated(resolver.mesh)
    return out


def dryrun_cell(arch: str, shape: str, multi_pod: bool = False,
                overrides: Dict[str, Any] = None,
                config_patch: Dict[str, Any] = None,
                accum_steps: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch)
    if config_patch:
        for k, v in config_patch.items():
            if k.endswith("dtype") and isinstance(v, str):
                v = {"bf16": jnp.bfloat16, "f32": jnp.float32}[v]
            setattr(cfg, k, v)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "status": "skipped",
                "multi_pod": multi_pod,
                "reason": "full-attention arch at 500k context (see DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = Model(cfg)
    resolver = Resolver(cfg, mesh, overrides=overrides)
    kind = SHAPES[shape]["kind"]
    t0 = time.time()

    params_boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = shardings_for(params_boxed, resolver)
    params_spec = unbox(params_boxed)
    batch_spec = batch_specs(cfg, shape)
    batch_sh = _batch_shardings(batch_spec, resolver)

    token = set_activation_resolver(resolver)
    try:
        with mesh:
            if kind == "train":
                opt = AdamW()
                opt_spec = jax.eval_shape(opt.init, params_spec)
                # moments shard exactly like their parameters
                opt_sh = {"m": params_sh, "v": params_sh,
                          "count": replicated(mesh)}
                step = make_train_step(model, opt, accum_steps=accum_steps)
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, opt_sh, batch_sh),
                    out_shardings=(params_sh, opt_sh, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(params_spec, opt_spec, batch_spec)
            elif kind == "prefill":
                cache_boxed = cache_specs(cfg, shape)
                cache_sh = shardings_for(cache_boxed, resolver)
                step = make_prefill_step(model, max_len=SHAPES[shape]["seq"])
                jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                                 out_shardings=(None, cache_sh))
                lowered = jitted.lower(params_spec, batch_spec)
            else:  # decode
                cache_boxed = cache_specs(cfg, shape)
                cache_sh = shardings_for(cache_boxed, resolver)
                cache_spec = unbox(cache_boxed)
                step = make_decode_step(model)
                jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, batch_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_spec, cache_spec, batch_spec)
            compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        return {"arch": arch, "shape": shape, "status": "failed",
                "multi_pod": multi_pod, "error": f"{type(e).__name__}: {e}"}
    finally:
        reset_activation_resolver(token)

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    terms = roofline_terms(cost, coll, n_dev)

    # analytic model FLOPs
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    s = SHAPES[shape]
    tokens = s["batch"] * (s["seq"] if kind != "decode" else 1)
    model_flops = ((6 if kind == "train" else 2) * n_active * tokens
                   + model_attention_flops(cfg, shape))
    hlo_flops_total = terms["flops_per_device"] * n_dev
    result = {
        "arch": arch, "shape": shape, "status": "ok", "multi_pod": multi_pod,
        "n_devices": n_dev, "kind": kind, "n_layers": cfg.n_layers,
        "compile_s": round(time.time() - t0, 1),
        "params": n_params, "active_params": n_active,
        "tokens": tokens, "model_flops": model_flops,
        "hlo_flops_total": hlo_flops_total,
        "useful_flops_ratio": (model_flops / hlo_flops_total
                               if hlo_flops_total else 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": coll,
        "roofline": terms,
        "dominant": max(("t_compute", "t_memory", "t_collective"),
                        key=lambda k: terms[k]),
    }
    return result


def model_attention_flops(cfg, shape: str) -> float:
    """Analytic attention FLOPs (causal → S²/2) for the MODEL_FLOPS term."""
    s = SHAPES[shape]
    B, S = s["batch"], s["seq"]
    kind = s["kind"]
    mult = 3 if kind == "train" else 1  # fwd + 2×bwd
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        n_attn, S_eff = cfg.n_layers, S
        dh_qk = dh_v = cfg.head_dim
    elif cfg.family == "mla_moe":
        n_attn, S_eff = cfg.n_layers, S
        dh_qk, dh_v = cfg.nope_head_dim + cfg.rope_head_dim, cfg.v_head_dim
    elif cfg.family == "hybrid":
        n_attn = len([i for i in range(cfg.n_layers)
                      if cfg.attn_every and i % cfg.attn_every == 0])
        S_eff, dh_qk, dh_v = S, cfg.head_dim, cfg.head_dim
    else:  # xlstm: attention-free
        return 0.0
    if kind == "decode":
        # one query over the full cache
        per_layer = 2 * B * cfg.n_heads * S * (dh_qk + dh_v)
    else:
        per_layer = 2 * B * cfg.n_heads * (S_eff ** 2 / 2) * (dh_qk + dh_v)
    return mult * n_attn * per_layer


# affine analysis probes: unrolled depths per family (chosen so heterogeneous
# block cadences — zamba's shared-attn sites, xlstm's sLSTM layers — appear at
# production density in the L2-L1 slope)
PROBE_POINTS = {"hybrid": (14, 26), "xlstm": (8, 16), "mla_moe": (3, 5)}
_EXTRAP_KEYS = ("flops_per_device", "bytes_per_device",
                "collective_bytes_per_device")


def analyze_cell(arch: str, shape: str, config_patch=None, overrides=None,
                 probe_patch=None, tag: str = "") -> Dict[str, Any]:
    """Production compile (scan, memory truth) + affine probe (unrolled,
    cost/collective truth) → extrapolated roofline terms."""
    cfg = get_config(arch)
    prod = dryrun_cell(arch, shape, multi_pod=False, overrides=overrides,
                       config_patch=config_patch)
    if prod["status"] != "ok":
        return prod
    L1, L2 = PROBE_POINTS.get(cfg.family, (2, 4))
    probes = []
    for L in (L1, L2):
        patch = {"n_layers": L, "scan_layers": False, "unroll_attention": True}
        patch.update(config_patch or {})
        patch.update(probe_patch or {})
        patch["n_layers"] = L
        r = dryrun_cell(arch, shape, multi_pod=False, overrides=overrides,
                        config_patch=patch)
        if r["status"] != "ok":
            r["probe_L"] = L
            return r
        probes.append(r)
    full_L = (config_patch or {}).get("n_layers", cfg.n_layers)
    extr = {}
    for key in _EXTRAP_KEYS:
        v1 = probes[0]["roofline"][key]
        v2 = probes[1]["roofline"][key]
        a = (v2 - v1) / (L2 - L1)
        extr[key] = v1 + a * (full_L - L1)
    from repro.distributed.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

    terms = {
        "t_compute": extr["flops_per_device"] / PEAK_FLOPS,
        "t_memory": extr["bytes_per_device"] / HBM_BW,
        "t_collective": extr["collective_bytes_per_device"] / ICI_BW,
        **extr,
    }
    n_dev = prod["n_devices"]
    hlo_total = extr["flops_per_device"] * n_dev
    result = dict(prod)
    result.update({
        "analysis": "affine_probe",
        "probe_points": [L1, L2],
        "probe_flops_per_device": [p["roofline"]["flops_per_device"] for p in probes],
        "probe_compile_s": [p["compile_s"] for p in probes],
        "roofline": terms,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": (prod["model_flops"] / hlo_total) if hlo_total else 0.0,
        "dominant": max(("t_compute", "t_memory", "t_collective"),
                        key=lambda k: terms[k]),
        "production_cost_raw": prod["roofline"],
    })
    return result


def save_result(res: Dict[str, Any], tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mp = "multi" if res.get("multi_pod") else "single"
    name = f"{res['arch']}_{res['shape']}_{mp}{tag}.json".replace("/", "_")
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="compile-proof only (skip roofline probes)")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in cells:
        mp_tag = "multi" if mp else "single"
        fname = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mp_tag}.json")
        if args.skip_existing and os.path.exists(fname):
            print(f"[skip-existing] {arch} × {shape} × {mp_tag}")
            continue
        if mp or args.no_probe:
            res = dryrun_cell(arch, shape, multi_pod=mp)  # compile-proof only
        else:
            res = analyze_cell(arch, shape)               # + roofline probes
        path = save_result(res)
        if res["status"] == "ok":
            n_ok += 1
            t = res["roofline"]
            print(f"[ok]   {arch} × {shape} × {mp_tag}: "
                  f"compute={t['t_compute']:.3e}s memory={t['t_memory']:.3e}s "
                  f"coll={t['t_collective']:.3e}s dominant={res['dominant']} "
                  f"({res['compile_s']}s compile) -> {path}")
        elif res["status"] == "skipped":
            n_skip += 1
            print(f"[skip] {arch} × {shape}: {res['reason']}")
        else:
            n_fail += 1
            print(f"[FAIL] {arch} × {shape} × {mp_tag}: {res['error']}")
    print(f"dry-run done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
