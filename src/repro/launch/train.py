"""Production training launcher.

    python -m repro.launch.train --arch llama3.2-3b --steps 100 \
        [--smoke] [--workdir DIR] [--batch 8 --seq 256] [--accum 1]

On this CPU container use --smoke (reduced config).  On a real TPU slice the
full config shards over the production mesh; the training loop itself is a
Triggerflow state-machine workflow (checkpoint/resume per chunk, event-replay
fault tolerance) — kill and relaunch to resume.
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config
from repro.training.trainer import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--chunk-steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    workdir = args.workdir or f"/tmp/tf-train-{cfg.arch}"
    print(f"arch={cfg.arch} params={cfg.param_count() / 1e6:.1f}M "
          f"workdir={workdir}")
    out = run_training(cfg, workdir, total_steps=args.steps,
                       chunk_steps=args.chunk_steps, batch=args.batch,
                       seq=args.seq, peak_lr=args.lr)
    print("status:", out["workflow_result"]["status"])
    for rec in out["history"]:
        print(f"  step {rec['step']:5d} loss {rec['loss_mean']:.4f} "
              f"({rec['wall_s']}s)")


if __name__ == "__main__":
    main()
