"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell — the
dry-run lowers against these; nothing is ever allocated."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models import Model, ModelConfig

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    s = SHAPES[shape_name]
    B, S = s["batch"], s["seq"]
    kind = s["kind"]
    if kind == "decode":
        if cfg.family == "audio":
            return {"tokens": _sds((B, cfg.codebooks, 1), I32)}
        return {"tokens": _sds((B, 1), I32)}
    if cfg.family == "audio":
        batch = {"tokens": _sds((B, cfg.codebooks, S), I32)}
        if kind == "train":
            batch["targets"] = _sds((B, cfg.codebooks, S), I32)
        return batch
    batch = {"tokens": _sds((B, S), I32)}
    if kind == "train":
        batch["targets"] = _sds((B, S), I32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
        batch["patch_positions"] = _sds((B, cfg.n_patches), I32)
        batch["positions3"] = _sds((B, S, 3), I32)
    return batch


def cache_specs(cfg: ModelConfig, shape_name: str):
    """Boxed cache shape tree (Param leaves with ShapeDtypeStruct values)."""
    s = SHAPES[shape_name]
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init_cache(s["batch"], s["seq"]))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Everything the step function needs, as ShapeDtypeStructs."""
    s = SHAPES[shape_name]
    out = {"kind": s["kind"], "batch": batch_specs(cfg, shape_name)}
    if s["kind"] == "decode":
        out["cache"] = cache_specs(cfg, shape_name)
    return out
