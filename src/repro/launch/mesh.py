"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any device initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
