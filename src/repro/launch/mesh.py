"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any device initialization.

``AxisType`` / ``axis_types=`` only exist on newer JAX; on older releases
(e.g. 0.4.x) meshes are implicitly "auto" so dropping the kwarg is
semantically equivalent.  ``compat_make_mesh`` is the version-safe entry
point used here and by the tests.
"""
from __future__ import annotations

import jax

try:  # JAX >= 0.5: explicit Auto/Explicit axis types
    from jax.sharding import AxisType

    _HAS_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed JAX
    AxisType = None
    _HAS_AXIS_TYPES = False


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported, plain otherwise."""
    if _HAS_AXIS_TYPES:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests / examples)."""
    n = len(jax.devices())
    return compat_make_mesh((n,), ("data",))
