import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: hypothesis → change → re-lower → re-analyse.

Each cell gets an ordered list of named variants (sharding-rule overrides /
model-config patches / step knobs).  Every variant re-runs the full affine
probe analysis and is logged to results/hillclimb/<cell>__<variant>.json;
the EXPERIMENTS.md §Perf table is generated from those files.

    python -m repro.launch.hillclimb --cell C            # one cell
    python -m repro.launch.hillclimb --all
"""

import argparse  # noqa: E402
import json  # noqa: E402
from typing import Any, Dict, List, Optional, Tuple  # noqa: E402

from repro.launch.dryrun import RESULTS_DIR, analyze_cell  # noqa: E402

HILL_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "hillclimb")


# (variant_name, hypothesis, overrides, config_patch, probe_patch, accum)
Variant = Tuple[str, str, Optional[Dict], Optional[Dict], Optional[Dict], int]

CELLS: Dict[str, Dict[str, Any]] = {
    "A": {
        "arch": "zamba2-1.2b", "shape": "train_4k",
        "why": "worst non-decode roofline fraction (0.013), memory-dominated",
        "variants": [
            ("remat_dots",
             "memory term is recompute-dominated: saving matmul outputs "
             "(dots policy) removes most backward recompute reads/writes; "
             "expect t_memory down 20-35%, t_compute down ~25% too",
             None, {"remat_policy": "dots"}, None, 1),
            ("ssd_chunk_256",
             "larger SSD chunks quarter the number of inter-chunk state "
             "round-trips ([B,H,N,P] states written/read per chunk) but double "
             "the decay-matrix bytes (Q² per chunk); net t_memory down ~10% "
             "for N·P=4096 >> Q=128",
             None, {"ssm_chunk": 256}, None, 1),
            ("no_fsdp",
             "d_model=2048 is small: FSDP all-gathers of every weight 3×/step "
             "cost more than replicating 1.2B params (2.4GB/dev); expect "
             "t_collective down sharply, memory unchanged-ish",
             {"embed": ()}, None, None, 1),
            ("ssd_chunk_64",
             "iteration 2 (ssd_chunk_256 refuted with +0.8%): the memory hog "
             "is the fp32 intra-chunk decay tensor B·nc·Q²·H·4B — QUADRATIC "
             "in Q, so SMALLER chunks win: Q=64 halves decay bytes "
             "(nc doubles, Q² quarters); predict t_memory −25-40%",
             None, {"ssm_chunk": 64}, None, 1),
            ("decay_bf16",
             "iteration 3 (chunk-size levers refuted: Q**2 tensor is not the "
             "bottleneck alone — the whole fp32 ELEMENTWISE CHAIN over "
             "[B,nc,Q,Q,H] is: broadcast-sub, exp, mask-mul, gate-mul each "
             "count full operands). Computing the decay chain in bf16 halves "
             "every operand in that chain; predict t_memory -20-35%",
             None, {"ssd_decay_dtype": "bf16"}, None, 1),
            ("combined_best",
             "stack the confirmed wins: dots remat + bf16 decay chain",
             None, {"remat_policy": "dots", "ssd_decay_dtype": "bf16"}, None, 1),
        ],
    },
    "B": {
        "arch": "deepseek-v2-236b", "shape": "train_4k",
        "why": "most collective-bound cell (t_coll=131s, 9.4× t_compute)",
        "variants": [
            ("capacity_1_0",
             "MoE dispatch traffic and expert FLOPs scale with the capacity "
             "factor; cf 1.25→1.0 cuts expert-side all-to-all/gather volume "
             "and padded expert compute by 20%",
             None, {"capacity_factor": 1.0}, None, 1),
            ("no_seq_shard",
             "activation seq-sharding between blocks forces two all-to-alls "
             "per layer (seq↔heads reshard); dropping it trades those for "
             "replicated-activation memory; expect t_collective down, "
             "t_memory up",
             {"act_seq": ()}, None, None, 1),
            ("experts_data",
             "routing experts over the data axis instead of model: token "
             "gather/scatter then crosses the axis tokens are already "
             "sharded on, halving cross-axis exchange volume",
             {"experts": ("data",), "embed": ()}, None, None, 1),
            ("combined_best",
             "stack the confirmed wins",
             {"act_seq": ()}, {"capacity_factor": 1.0}, None, 1),
        ],
    },
    "C": {
        "arch": "deepseek-67b", "shape": "decode_32k",
        "why": "decode/serving cell with pathological 4.1s/token collectives "
               "(the HLO shows 2×2GB KV-cache all-gathers per layer)",
        "variants": [
            ("cache_seq_sharded",
             "pin the KV cache to (batch→data, seq→model): attention becomes "
             "a partial softmax over seq shards (tiny stat all-reduces) "
             "instead of all-gathering 2GB of cache per layer; expect "
             "t_collective down >100×, t_memory down ~16× (cache reads "
             "sharded)",
             {"seq_kv": ("model",)}, None, None, 1),
            ("cache_seq_sharded_batch_model",
             "additionally let the 128-seq batch use leftover capacity — "
             "keep seq→model and verify logits path isn't regressed",
             {"seq_kv": ("model",), "vocab": ("model",)}, None, None, 1),
        ],
    },
}


def run_cell(cell_key: str, only: Optional[str] = None,
             reuse_baseline: bool = False) -> List[dict]:
    os.makedirs(HILL_DIR, exist_ok=True)
    spec = CELLS[cell_key]
    arch, shape = spec["arch"], spec["shape"]
    results = []
    base_path = os.path.join(HILL_DIR,
                             f"{cell_key}_{arch}_{shape}__baseline.json")
    if reuse_baseline and os.path.exists(base_path):
        base = json.load(open(base_path))
    else:
        base = analyze_cell(arch, shape)
    base["variant"] = "baseline"
    base["hypothesis"] = spec["why"]
    _save(cell_key, "baseline", base)
    results.append(base)
    _report(cell_key, base, base)
    for name, hypothesis, overrides, patch, probe_patch, accum in spec["variants"]:
        if only and name != only:
            continue
        res = analyze_cell(arch, shape, config_patch=patch, overrides=overrides,
                           probe_patch=probe_patch)
        res["variant"] = name
        res["hypothesis"] = hypothesis
        _save(cell_key, name, res)
        results.append(res)
        _report(cell_key, res, base)
    return results


def _save(cell_key: str, variant: str, res: dict) -> None:
    spec = CELLS[cell_key]
    path = os.path.join(
        HILL_DIR, f"{cell_key}_{spec['arch']}_{spec['shape']}__{variant}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)


def _report(cell_key: str, res: dict, base: dict) -> None:
    if res.get("status") != "ok":
        print(f"[{cell_key}:{res.get('variant')}] FAILED: {res.get('error')}")
        return
    t, tb = res["roofline"], base["roofline"]
    dom = base["dominant"]
    delta = (t[dom] - tb[dom]) / tb[dom] * 100 if tb[dom] else 0.0
    print(f"[{cell_key}:{res['variant']:28s}] compute={t['t_compute']:.3e} "
          f"memory={t['t_memory']:.3e} coll={t['t_collective']:.3e} "
          f"| baseline-dominant {dom} {delta:+.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reuse-baseline", action="store_true")
    args = ap.parse_args()
    cells = list(CELLS) if (args.all or not args.cell) else [args.cell]
    for c in cells:
        run_cell(c, only=args.variant, reuse_baseline=args.reuse_baseline)


if __name__ == "__main__":
    main()
