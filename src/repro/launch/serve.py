"""Serving launcher: trigger-batched generation with scale-to-zero.

    python -m repro.launch.serve --arch yi-9b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

from repro.configs import ARCHS, get_config
from repro.core import KedaAutoscaler, Triggerflow
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--metrics-dump", metavar="PREFIX", default=None,
                    help="on exit, write the aggregated metrics snapshot to "
                         "PREFIX.prom (Prometheus text) and PREFIX.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tf = Triggerflow(inline_functions=True)
    eng = ServingEngine(cfg, tf, "serve", max_batch=args.max_batch,
                        max_new_tokens=args.max_new_tokens, max_len=256)
    eng.deploy()
    scaler = KedaAutoscaler(tf, poll_interval=0.05, grace_period=0.5).start()
    t0 = time.time()
    try:
        for i in range(args.requests):
            eng.submit(f"req-{i}", [1 + i, 2 + i, 3 + i])
        while eng.served < args.requests and time.time() - t0 < 300:
            time.sleep(0.05)
        print(f"served {eng.served} requests in {eng.batches} batches, "
              f"{time.time() - t0:.1f}s")
    finally:
        # order matters: stop() drains any in-flight autoscaler tick (one
        # caught mid-start_shards would otherwise provision workers *after*
        # shutdown began, leaving them unreaped), then shutdown reclaims
        # everything the drained tick started.
        scaler.stop()
        if args.metrics_dump:
            # scrape before shutdown tears the workers down: the snapshot
            # folds every worker/shard registry + the autoscaler's counters
            from repro.obs.metrics import dump_metrics, merge_snapshot
            snap = tf.metrics_snapshot()
            merge_snapshot(snap, scaler.metrics_snapshot())
            for path in dump_metrics(snap, args.metrics_dump):
                print(f"metrics dumped to {path}")
        tf.shutdown()


if __name__ == "__main__":
    main()
