"""``obs-discipline``: metrics are batch-granular, never per-event.

The metrics plane's CI-gated overhead budget (≤10% on the noop action
plane) holds because the hot path records O(1) metric updates per *batch*
(``Histogram.observe_batch``), not per event.  This rule flags
``Counter.inc`` / ``Histogram.observe`` calls lexically
inside a ``for``/``while`` loop — the shape that silently reintroduces
O(events) instrument updates (and double-counting, PR 6's dlq bug) when a
batched path grows a per-item loop.

``observe_batch`` is the sanctioned call and is never flagged.  A scalar
update inside a *cold* loop (scrape aggregation, shutdown paths) is a
legitimate exception: pragma it with the reason.
"""
from __future__ import annotations

import ast
from typing import List, Sequence

from .core import Finding, Rule, SourceFile

_SCALAR_METRIC_ATTRS = ("inc", "observe")


class ObsDiscipline(Rule):
    id = "obs-discipline"
    invariant = ("No scalar metric updates (.inc()/.observe()) inside "
                 "per-item loops; hot paths record per batch via "
                 "observe_batch.")
    motivation = ("PR 6: the metrics plane's <=10% overhead gate and the "
                  "dlq double-count fix both rest on batch-granular "
                  "recording.")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            for qual, cls, fn in sf.functions():
                self._visit(sf, fn, False, out)
        return out

    def _visit(self, sf: SourceFile, node: ast.AST, in_loop: bool,
               out: List[Finding]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            now = in_loop or isinstance(child, (ast.For, ast.While))
            if in_loop and isinstance(child, ast.Call):
                f = child.func
                if isinstance(f, ast.Attribute) and \
                        f.attr in _SCALAR_METRIC_ATTRS:
                    self._finding(
                        sf, child, "scalar metric .%s() inside a loop — "
                        "record per batch (observe_batch) instead" % f.attr,
                        out)
            self._visit(sf, child, now, out)
