"""``seam-safety``: no handler may swallow an exception without a trace.

A bare ``except:`` or blanket ``except Exception:`` whose body neither
re-raises, nor calls anything (logging, ``traceback.print_exc``, a metrics
bump), nor records state (an assignment a caller can observe) is a silent
swallow — the failure class where a shard "hangs" with no evidence because
its real error evaporated in a handler.

The codebase's sanctioned blanket-except idiom always does one of:

* re-raise after cleanup (``except Exception: ...; raise``),
* ``traceback.print_exc()`` + drop the shard through an accounted path,
* degrade a diagnostic to a placeholder (``lag = "?"``) — an assignment.

All of those pass.  Only the truly silent body (``pass`` / ``continue`` /
bare ``return``/constant) is flagged; a deliberate best-effort swallow gets
a pragma with its reason.
"""
from __future__ import annotations

import ast
from typing import List, Sequence

from .core import Finding, Rule, SourceFile

_BLANKET = ("Exception", "BaseException")


def _is_blanket(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    if isinstance(t, ast.Name) and t.id in _BLANKET:
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name) and e.id in _BLANKET
                   for e in t.elts)
    return False


def _is_silent(handler: ast.ExceptHandler) -> bool:
    """True when the body can neither surface nor record the exception."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return False
        if isinstance(node, ast.Call):
            return False
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            return False
        if isinstance(node, ast.Return) and node.value is not None \
                and not isinstance(node.value, ast.Constant):
            return False
    return True


class SeamSafety(Rule):
    id = "seam-safety"
    invariant = ("No bare/blanket except swallows an exception silently: "
                 "the handler re-raises, calls something (trace/log/metric) "
                 "or records state.")
    motivation = ("Worker/pool hot-path failures must leave evidence; a "
                  "silent swallow turns a crashed shard into an "
                  "undebuggable hang.")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ExceptHandler) and \
                        _is_blanket(node) and _is_silent(node):
                    # a pragma anywhere inside the handler blesses it (the
                    # natural place to document a deliberate swallow is the
                    # swallowing body itself)
                    end = getattr(node, "end_lineno", node.lineno) or \
                        node.lineno
                    if any(sf.allowed(ln, self.id)
                           for ln in range(node.lineno, end + 1)):
                        continue
                    what = "bare except" if node.type is None else \
                        "blanket except Exception"
                    self._finding(
                        sf, node, "%s swallows the exception silently "
                        "(no raise, no call, no recorded state)" % what, out)
        return out
