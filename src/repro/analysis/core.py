"""Shared analysis core for ``tfcheck`` (see ``scripts/tfcheck.py``).

The rules in this package encode the codebase's concurrency/durability
invariants (ARCHITECTURE.md §10) as small AST visitors.  This module owns
everything the rules share:

* ``SourceFile`` — one parsed file: AST, source lines, per-line pragma map,
  and a node→qualified-name index so findings name the function they hit.
* ``Finding`` — one rule violation, keyed for the baseline ratchet.
* pragma parsing — ``# tfcheck: allow[rule] reason`` on the offending line
  (or the line directly above it) suppresses that rule there.  The reason
  string is mandatory by convention: a pragma is a *documented* exception.
* baseline/ratchet — a committed JSON baseline maps finding keys to counts;
  a run fails only on findings *above* its baseline count (new code can't
  add violations; burned-down ones can't come back because
  ``--write-baseline`` shrinks the file).

Rules are deliberately lexical-first: they look at what a function does
while it *textually* holds a lock / before it *textually* renames a file,
with at most one level of in-file call resolution (``callers_of``).  That
keeps every rule small, predictable, and explainable in one error line —
the property that makes a lint gate survivable in CI.
"""
from __future__ import annotations

import ast
import json
import os
import re
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

PRAGMA_RE = re.compile(r"#\s*tfcheck:\s*allow\[([A-Za-z0-9_,\- ]+)\]\s*(.*)")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``key`` intentionally excludes the line number: the ratchet compares
    per-(rule, file, function) *counts*, so unrelated edits that shift
    lines don't churn the baseline.
    """

    rule: str
    path: str       # repo-relative path
    line: int
    context: str    # qualified function/class ("" for module level)
    message: str

    @property
    def key(self) -> str:
        return "%s:%s:%s" % (self.rule, self.path, self.context or "<module>")

    def render(self) -> str:
        where = " (in %s)" % self.context if self.context else ""
        return "%s:%d: [%s] %s%s" % (self.path, self.line, self.rule,
                                     self.message, where)


class SourceFile:
    """A parsed source file plus the per-line pragma map."""

    def __init__(self, path: str, rel: str, text: str) -> None:
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        # line -> set of allowed rule ids.  A pragma covers its own line and
        # the next one, so it works both trailing and standalone-above.
        self.allow: Dict[int, set] = {}
        for i, ln in enumerate(self.lines, 1):
            m = PRAGMA_RE.search(ln)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.allow.setdefault(i, set()).update(rules)
                self.allow.setdefault(i + 1, set()).update(rules)
        # node -> enclosing qualified name ("Class.method")
        self._qual: Dict[ast.AST, str] = {}
        self._index_quals(self.tree, ())
        # class name -> list of base-class names (in-file resolution only)
        self.class_bases: Dict[str, List[str]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                self.class_bases[node.name] = [
                    b.id for b in node.bases if isinstance(b, ast.Name)]

    def _index_quals(self, node: ast.AST, stack: Tuple[str, ...]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                child_stack = stack + (child.name,)
                self._qual[child] = ".".join(child_stack)
                self._index_quals(child, child_stack)
            else:
                self._qual[child] = ".".join(stack)
                self._index_quals(child, stack)

    def qualname(self, node: ast.AST) -> str:
        return self._qual.get(node, "")

    def allowed(self, line: int, rule: str) -> bool:
        return rule in self.allow.get(line, ())

    def functions(self) -> List[Tuple[str, Optional[str], ast.AST]]:
        """Every function in the file as (qualname, class name or None, node)."""
        out = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = self._qual.get(node, node.name)
                parts = qual.split(".")
                cls = parts[-2] if len(parts) >= 2 else None
                # a nested function's "class" slot may actually be a function;
                # resolve against known classes
                if cls is not None and cls not in self.class_bases:
                    cls = None
                out.append((qual, cls, node))
        return out


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def walk_no_nested_functions(node: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested function
    definitions or lambdas (their bodies run at *call* time, not while the
    enclosing lock/region is held)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


#: with-item context-manager call names that take the cross-process flock.
FLOCK_CTX_NAMES = ("_plock", "_flock", "_wf_flock")


def with_lock_items(node: ast.With) -> List[str]:
    """Thread-lock names acquired by a ``with`` statement.

    Matches bare attribute chains whose final attribute ends in ``lock``
    (``self._lock``, ``shard.lock``, ``worker.lock``) — NOT context-manager
    *calls* like ``self._plock(fp)``, which are flocks (see
    ``with_flock_items``).  fsync-under-flock is required by the durability
    invariant, so the two kinds must never be conflated.
    """
    out = []
    for item in node.items:
        expr = item.context_expr
        name = dotted_name(expr)
        if name is not None and name.rsplit(".", 1)[-1].endswith("lock"):
            out.append(name)
    return out


def with_flock_items(node: ast.With) -> List[str]:
    """Flock context-manager names entered by a ``with`` statement."""
    out = []
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Call):
            name = call_name(expr)
            if name is not None and \
                    name.rsplit(".", 1)[-1] in FLOCK_CTX_NAMES:
                out.append(name)
    return out


def callers_of(sf: SourceFile, func_name: str) -> List[Tuple[ast.AST, ast.Call]]:
    """In-file call sites of ``func_name`` as (enclosing function, call).

    One level only, by name — enough to bless small helpers (``_append_clean``)
    whose callers all hold the required context, without growing a real
    interprocedural engine.
    """
    out = []
    for _, _, fn in sf.functions():
        for n in walk_no_nested_functions(fn):
            if isinstance(n, ast.Call):
                cn = call_name(n)
                if cn is not None and cn.rsplit(".", 1)[-1] == func_name:
                    out.append((fn, n))
    return out


class Rule:
    """Base class: one invariant, one ``check`` over the parsed files."""

    id: str = ""
    invariant: str = ""
    motivation: str = ""

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        raise NotImplementedError

    def _finding(self, sf: SourceFile, node: ast.AST, message: str,
                 out: List[Finding]) -> None:
        line = getattr(node, "lineno", 1)
        if sf.allowed(line, self.id):
            return
        out.append(Finding(self.id, sf.rel, line, sf.qualname(node), message))


# -- file loading ---------------------------------------------------------------

def load_paths(paths: Iterable[str], root: Optional[str] = None
               ) -> List[SourceFile]:
    """Parse every ``.py`` under the given files/directories."""
    root = os.path.abspath(root or os.getcwd())
    files: List[SourceFile] = []
    seen = set()
    for p in paths:
        p = os.path.abspath(p)
        cands: List[str] = []
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                cands.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        elif p.endswith(".py"):
            cands.append(p)
        for c in cands:
            if c in seen:
                continue
            seen.add(c)
            rel = os.path.relpath(c, root)
            with open(c, encoding="utf-8") as f:
                files.append(SourceFile(c, rel, f.read()))
    return files


# -- baseline / ratchet ---------------------------------------------------------

def load_baseline(path: str) -> Dict[str, int]:
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except OSError:
        return {}
    findings = data.get("findings", {})
    return {str(k): int(v) for k, v in findings.items()}


def write_baseline(findings: Sequence[Finding], path: str) -> None:
    counts = Counter(f.key for f in findings)
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "findings": dict(sorted(counts.items()))},
                  f, indent=2, sort_keys=True)
        f.write("\n")


def ratchet(findings: Sequence[Finding], baseline: Dict[str, int]
            ) -> List[Finding]:
    """Findings above their baselined count — the ones that fail the gate.

    For a key baselined at N, the first N findings are forgiven and any
    beyond N are returned (new code added a violation).  Keys absent from
    the baseline get everything returned.
    """
    used: Counter = Counter()
    out = []
    for f in findings:
        used[f.key] += 1
        if used[f.key] > baseline.get(f.key, 0):
            out.append(f)
    return out
