"""Lock rules: blocking work under thread locks + static lock-order cycles.

``lock-discipline`` — nothing slow or blocking may run while a
``threading.Lock``/``RLock`` is *textually* held: no ``time.sleep``, no
``os.fsync``, no ``subprocess`` calls, no socket sends, and no command-pipe
waits (``conn.poll``/``conn.recv``).  Pipe waits and sleeps additionally
propagate one file deep through ``self._helper()`` calls (fixpoint within
the class), because the process-pool control plane hides its waits behind
``_request``/``_await`` helpers.  ``os.fsync`` is checked lexically only:
the durable stores *require* fsync under their cross-process flock, and
chasing it interprocedurally would set this rule at war with the
durability-ordering rule.  Striped-lock design note: ``SegmentLog`` owns
every durable write, so a shard mirror that fsyncs *directly* under its
lock is always a bug.

``lock-order`` — build the static lock-acquisition graph (lexically nested
``with`` blocks plus one level of cross-file method resolution) and fail on
any cycle.  Node identity folds ``self.<attr>`` through the class's base
chain (``ShardWorker.lock`` is ``TFWorker.lock``) and maps the repo's
conventional receiver names (``worker``, ``shard``, ``fp.shard``) to their
classes, so the same lock seen from two sides is one node.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .core import (Finding, Rule, SourceFile, call_name, dotted_name,
                   walk_no_nested_functions, with_flock_items,
                   with_lock_items)

#: Receiver-name conventions → class owning the attribute.  Small and
#: explicit on purpose: a wrong guess here would merge two different locks
#: into one node and fabricate cycles.
RECEIVER_CLASSES = {
    "worker": "TFWorker",
    "w": "TFWorker",
    "shard": "StreamShard",
    "fp.shard": "StreamShard",
}

_PIPE_WAIT_ATTRS = ("poll", "recv")
_SOCKET_SEND_ATTRS = ("sendall", "sendto")


def _is_pipe_wait(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in _PIPE_WAIT_ATTRS:
        recv = dotted_name(f.value) or ""
        return "conn" in recv.rsplit(".", 1)[-1]
    return False


def _direct_violation(call: ast.Call) -> Optional[str]:
    """A call that must never run under a thread lock, or None."""
    name = call_name(call) or ""
    if name == "time.sleep":
        return "time.sleep"
    if name == "os.fsync":
        return "os.fsync (durable writes belong to SegmentLog, under the flock)"
    if name.startswith("subprocess."):
        return name
    f = call.func
    if isinstance(f, ast.Attribute):
        if f.attr in _SOCKET_SEND_ATTRS:
            return "socket %s" % f.attr
        if f.attr == "send":
            recv = dotted_name(f.value) or ""
            if "sock" in recv.rsplit(".", 1)[-1]:
                return "socket send"
    if _is_pipe_wait(call):
        return "command-pipe %s" % call.func.attr  # type: ignore[union-attr]
    return None


def _blocking_methods(sf: SourceFile) -> Dict[Optional[str], Set[str]]:
    """Per class: methods that (transitively, in-file) wait on a command
    pipe or sleep.  fsync/subprocess/socket do NOT propagate — see module
    docstring."""
    per_class: Dict[Optional[str], Dict[str, Set[str]]] = {}
    for qual, cls, fn in sf.functions():
        calls: Set[str] = set()
        direct = False
        for n in walk_no_nested_functions(fn):
            if isinstance(n, ast.Call):
                if _is_pipe_wait(n) or (call_name(n) == "time.sleep"):
                    direct = True
                cn = call_name(n)
                if cn is not None and cn.startswith("self."):
                    calls.add(cn.split(".", 1)[1].split(".")[0])
        per_class.setdefault(cls, {})[fn.name] = calls if not direct else \
            calls | {"__direct__"}
    out: Dict[Optional[str], Set[str]] = {}
    for cls, methods in per_class.items():
        blocking = {m for m, c in methods.items() if "__direct__" in c}
        changed = True
        while changed:
            changed = False
            for m, c in methods.items():
                if m not in blocking and c & blocking:
                    blocking.add(m)
                    changed = True
        out[cls] = blocking
    return out


class LockDiscipline(Rule):
    id = "lock-discipline"
    invariant = ("No blocking work (sleep, fsync, subprocess, socket send, "
                 "command-pipe wait) while a threading lock is held; pipe "
                 "waits/sleeps are traced one call deep through self-helpers.")
    motivation = ("PR 4/5: the striped shard locks are the publish/consume "
                  "hot path — one fsync or pipe wait under them serializes "
                  "every sibling shard (the notify-bump stall class of bug).")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            blocking = _blocking_methods(sf)
            for qual, cls, fn in sf.functions():
                cls_blocking = blocking.get(cls, set())
                for node in walk_no_nested_functions(fn):
                    if not isinstance(node, ast.With):
                        continue
                    locks = with_lock_items(node)
                    if not locks:
                        continue
                    held = " + ".join(locks)
                    for n in walk_no_nested_functions(node):
                        if not isinstance(n, ast.Call):
                            continue
                        why = _direct_violation(n)
                        if why is not None:
                            self._finding(
                                sf, n, "%s under %s" % (why, held), out)
                            continue
                        cn = call_name(n)
                        if cn is not None and cn.startswith("self."):
                            meth = cn.split(".", 1)[1].split(".")[0]
                            if meth != fn.name and meth in cls_blocking:
                                self._finding(
                                    sf, n,
                                    "command-pipe wait/sleep under %s via "
                                    "self.%s()" % (held, meth), out)
        return out


# -- static lock-order graph ---------------------------------------------------

def _root_class(sf_by_class: Dict[str, SourceFile], cls: str) -> str:
    """Fold a class through its (in-corpus, single-inheritance) base chain."""
    seen = set()
    while cls in sf_by_class and cls not in seen:
        seen.add(cls)
        bases = sf_by_class[cls].class_bases.get(cls, [])
        nxt = next((b for b in bases if b in sf_by_class), None)
        if nxt is None:
            return cls
        cls = nxt
    return cls


def _node_name(expr_name: str, cls: Optional[str],
               sf_by_class: Dict[str, SourceFile]) -> str:
    """Canonical graph node for an acquired lock name."""
    recv, _, attr = expr_name.rpartition(".")
    if recv == "self" and cls is not None:
        return "%s.%s" % (_root_class(sf_by_class, cls), attr)
    mapped = RECEIVER_CLASSES.get(recv)
    if mapped is not None:
        return "%s.%s" % (_root_class(sf_by_class, mapped), attr)
    return expr_name  # unknown receiver: keep it distinct, never merge


def build_lock_graph(files: Sequence[SourceFile]
                     ) -> Tuple[Dict[str, Set[str]],
                                Dict[Tuple[str, str], Tuple[str, int]]]:
    """The static acquisition graph: edge A→B when B is acquired (lexically,
    or via one resolved method call) while A is held.  Returns (adjacency,
    edge → (file, line) provenance)."""
    sf_by_class: Dict[str, SourceFile] = {}
    for sf in files:
        for cls in sf.class_bases:
            sf_by_class.setdefault(cls, sf)

    # method name -> list of (class, canonical lock nodes acquired directly)
    method_locks: Dict[str, List[Tuple[Optional[str], Set[str]]]] = {}
    for sf in files:
        for qual, cls, fn in sf.functions():
            acquired: Set[str] = set()
            for n in walk_no_nested_functions(fn):
                if isinstance(n, ast.With):
                    for name in with_lock_items(n):
                        acquired.add(_node_name(name, cls, sf_by_class))
                    for name in with_flock_items(n):
                        recv, _, attr = name.rpartition(".")
                        owner = _root_class(sf_by_class, cls) \
                            if recv == "self" and cls else recv
                        acquired.add("%s.%s" % (owner, attr))
            method_locks.setdefault(fn.name, []).append((cls, acquired))

    adj: Dict[str, Set[str]] = {}
    prov: Dict[Tuple[str, str], Tuple[str, int]] = {}

    def add_edge(a: str, b: str, sf: SourceFile, line: int) -> None:
        if a == b:
            return
        adj.setdefault(a, set()).add(b)
        adj.setdefault(b, set())
        prov.setdefault((a, b), (sf.rel, line))

    def callee_locks(call: ast.Call) -> Set[str]:
        """Locks a resolved callee acquires directly; {} when ambiguous."""
        f = call.func
        if not isinstance(f, ast.Attribute):
            return set()
        cands = method_locks.get(f.attr, [])
        cands = [(c, locks) for c, locks in cands if locks]
        if not cands:
            return set()
        recv = dotted_name(f.value) or ""
        mapped = RECEIVER_CLASSES.get(recv)
        if mapped is not None:
            root = _root_class(sf_by_class, mapped)
            cands = [(c, locks) for c, locks in cands
                     if c and _root_class(sf_by_class, c) == root]
        union = set().union(*(locks for _, locks in cands)) if cands else set()
        first = cands[0][1] if cands else set()
        # several classes define the method: only use the result when they
        # all acquire the same nodes — a wrong merge fabricates cycles
        if all(locks == first for _, locks in cands):
            return first
        return union if len(cands) == 1 else set()

    for sf in files:
        for qual, cls, fn in sf.functions():
            def visit(node: ast.AST, held: List[str]) -> None:
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef, ast.Lambda)):
                        continue
                    if isinstance(child, ast.With):
                        here = [
                            _node_name(nm, cls, sf_by_class)
                            for nm in with_lock_items(child)]
                        for nm in with_flock_items(child):
                            recv, _, attr = nm.rpartition(".")
                            owner = _root_class(sf_by_class, cls) \
                                if recv == "self" and cls else recv
                            here.append("%s.%s" % (owner, attr))
                        # re-acquiring an already-held node is the RLock
                        # idiom, not an ordering edge
                        here = [b for b in here if b not in held]
                        for h in held:
                            for b in here:
                                add_edge(h, b, sf, child.lineno)
                        for i, a in enumerate(here):
                            for b in here[i + 1:]:
                                add_edge(a, b, sf, child.lineno)
                        visit(child, held + here)
                        continue
                    if isinstance(child, ast.Call) and held:
                        for b in callee_locks(child):
                            if b in held:
                                continue  # re-entrant RLock, not an edge
                            for h in held:
                                add_edge(h, b, sf, child.lineno)
                    visit(child, held)
            visit(fn, [])
    return adj, prov


def find_cycle(adj: Dict[str, Set[str]]) -> Optional[List[str]]:
    """One cycle as [a, b, ..., a], or None if the graph is a DAG."""
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in adj}
    stack: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GREY
        stack.append(n)
        for m in sorted(adj.get(n, ())):
            if color.get(m, WHITE) == GREY:
                i = stack.index(m)
                return stack[i:] + [m]
            if color.get(m, WHITE) == WHITE:
                got = dfs(m)
                if got is not None:
                    return got
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(adj):
        if color[n] == WHITE:
            got = dfs(n)
            if got is not None:
                return got
    return None


class LockOrder(Rule):
    id = "lock-order"
    invariant = ("The static lock-acquisition graph (nested with-blocks + "
                 "one level of method resolution) must be acyclic.")
    motivation = ("The pool→worker→store→flock nesting is the system's "
                  "global lock order; any new path acquiring it backwards "
                  "is a latent deadlock the tests may never schedule.")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        adj, prov = build_lock_graph(files)
        cycle = find_cycle(adj)
        if cycle is None:
            return []
        edges = list(zip(cycle, cycle[1:]))
        where = prov.get(edges[0], ("?", 0))
        detail = "; ".join(
            "%s->%s (%s:%d)" % (a, b, *prov.get((a, b), ("?", 0)))
            for a, b in edges)
        return [Finding(self.id, where[0], where[1], "",
                        "lock-order cycle: %s" % detail)]
