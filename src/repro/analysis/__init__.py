"""``repro.analysis`` — the tfcheck invariant-checking plane.

Static AST rules over ``repro.core``/``repro.bus`` (run via
``scripts/tfcheck.py``, gated in CI with a baseline ratchet) plus a runtime
lock-order recorder (``locktrace``) that runs under the tier-1 suite when
``TFCHECK_TRACE_LOCKS`` is set.  The rule catalogue lives here so the CLI's
``--list-rules`` and ARCHITECTURE.md §10 stay one source of truth.
"""
from __future__ import annotations

from typing import List, Sequence

from .core import (Finding, Rule, SourceFile, load_baseline, load_paths,
                   ratchet, write_baseline)
from .durability import DurabilityOrdering
from .fencing import Fencing
from .lockrules import LockDiscipline, LockOrder
from .obsrules import ObsDiscipline
from .seams import SeamSafety

#: Every static rule, in reporting order.
ALL_RULES = (
    LockDiscipline(),
    LockOrder(),
    DurabilityOrdering(),
    Fencing(),
    ObsDiscipline(),
    SeamSafety(),
)


def rules_by_id():
    return {r.id: r for r in ALL_RULES}


def run_rules(files: Sequence[SourceFile],
              rules: Sequence[Rule] = ALL_RULES) -> List[Finding]:
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.check(files))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


__all__ = [
    "ALL_RULES", "Finding", "Rule", "SourceFile", "load_baseline",
    "load_paths", "ratchet", "rules_by_id", "run_rules", "write_baseline",
]
