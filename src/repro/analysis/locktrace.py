"""Runtime lock-order recorder (the dynamic half of ``tfcheck``).

When ``TFCHECK_TRACE_LOCKS`` is set, ``install()`` replaces
``threading.Lock``/``threading.RLock`` with tracing wrappers, ``fcntl.flock``
with a recording shim, and ``time.sleep`` with a held-lock auditor.  While
the tier-1 suite runs, every thread keeps a stack of currently-held locks
(identified by their *allocation site* — ``pool.py:214`` is one lock class,
however many instances exist), and each acquisition records edges
``held → acquired`` into a global graph.

After the run, ``check()`` asserts:

* the runtime acquisition-order graph is **acyclic** — the dynamic twin of
  the static ``lock-order`` rule, catching orders the AST can't see
  (callbacks, store objects threaded through the pools), and
* ``time.sleep`` was never called while a bus-infrastructure lock was held
  (worker locks are exempt: actions legitimately run — and may sleep —
  under the shard worker's batch lock).

Zero-cost when off: nothing is imported into the hot path and nothing is
patched unless ``install()`` runs; ``scripts/perf_gate.py`` holds the
flag-unset overhead to within 2%.

The wrappers forward ``_is_owned``/``_release_save``/``_acquire_restore``
via ``__getattr__``, so ``threading.Condition`` built on a traced lock
works; a ``Condition.wait`` window shows the lock as held while the thread
is blocked in the wait, which cannot add false edges (that thread acquires
nothing until ``wait`` returns with the lock re-held).
"""
from __future__ import annotations

import os
import sys
import threading
import time
from typing import Dict, List, Optional, Set, Tuple

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None  # type: ignore[assignment]

_real_Lock = threading.Lock
_real_RLock = threading.RLock
_real_flock = fcntl.flock if fcntl is not None else None
_real_sleep = time.sleep

#: Lock sites whose holders may sleep: the shard worker's batch lock is
#: held across user condition/action code by design (the action *is* the
#: work), and the simulated function backend sleeps to model duration.
#: The autoscaler's tick lock serializes the control loop across slow pool
#: calls (start_shards forks processes; stop() drains through the lock) —
#: blocking under it is its documented contract, not a hot-path hazard.
SLEEP_EXEMPT_SITES = ("worker.py:", "autoscaler.py:")

_installed = False
_state: Optional["_TraceState"] = None


class _TraceState:
    def __init__(self) -> None:
        self.guard = _real_Lock()
        self.edges: Dict[Tuple[str, str], Tuple[int, str]] = {}
        self.nodes: Set[str] = set()
        self.acquisitions = 0
        self.sleep_violations: List[Tuple[str, Tuple[str, ...]]] = []
        self.local = threading.local()

    def held(self) -> List[str]:
        stack = getattr(self.local, "stack", None)
        if stack is None:
            stack = self.local.stack = []
        return stack

    def on_acquire(self, site: str) -> None:
        stack = self.held()
        if stack:
            caller = _caller_site()
            with self.guard:
                self.nodes.add(site)
                for h in stack:
                    if h != site:
                        n, first = self.edges.get((h, site), (0, caller))
                        self.edges[(h, site)] = (n + 1, first)
        else:
            with self.guard:
                self.nodes.add(site)
        with self.guard:
            self.acquisitions += 1
        stack.append(site)

    def on_release(self, site: str) -> None:
        stack = self.held()
        # release order can differ from acquire order (overlapping scopes):
        # drop the most recent matching entry
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == site:
                del stack[i]
                return


def _caller_site(skip: int = 2) -> str:
    f = sys._getframe(skip)
    while f is not None:
        fn = f.f_code.co_filename
        if not fn.endswith("locktrace.py") and "threading" not in fn:
            return "%s:%d" % (os.path.basename(fn), f.f_lineno)
        f = f.f_back
    return "?:0"


class _TracedLock:
    """Wrapper over a real lock; records acquisition order by site."""

    __slots__ = ("_lk", "_site", "_depth")

    def __init__(self, lk, site: str) -> None:
        self._lk = lk
        self._site = site
        self._depth = 0  # RLock re-entrancy: record the 0→1 edge only

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._lk.acquire(blocking, timeout)
        if ok:
            self._depth += 1
            if self._depth == 1 and _state is not None:
                _state.on_acquire(self._site)
        return ok

    def release(self) -> None:
        if self._depth > 0:
            self._depth -= 1
            if self._depth == 0 and _state is not None:
                _state.on_release(self._site)
        self._lk.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._lk.locked()

    def __getattr__(self, name):
        # Condition support: _is_owned / _acquire_restore / _release_save
        # go straight to the real lock.  During a cv.wait the stack keeps
        # showing this lock held, which is sound (see module docstring).
        return getattr(self._lk, name)


def _traced_lock_factory():
    return _TracedLock(_real_Lock(), _caller_site())


def _traced_rlock_factory():
    return _TracedLock(_real_RLock(), _caller_site())


_FLOCK_FD_SITES: Dict[int, str] = {}


def _flock_site(fd) -> str:
    fileno = fd if isinstance(fd, int) else fd.fileno()
    site = _FLOCK_FD_SITES.get(fileno)
    if site is None:
        try:
            path = os.readlink("/proc/self/fd/%d" % fileno)
            base = os.path.basename(path)
            # fold instance numbering: p0007.lock -> pN.lock
            base = "".join("N" if c.isdigit() else c for c in base)
            while "NN" in base:
                base = base.replace("NN", "N")
            site = "flock:%s" % base
        except OSError:  # pragma: no cover
            site = "flock:fd"
        _FLOCK_FD_SITES[fileno] = site
    return site


def _traced_flock(fd, op) -> None:
    _real_flock(fd, op)  # type: ignore[misc]
    if _state is None or fcntl is None:
        return
    site = _flock_site(fd)
    if op & fcntl.LOCK_UN:
        _FLOCK_FD_SITES.pop(fd if isinstance(fd, int) else fd.fileno(), None)
        _state.on_release(site)
    elif op & (fcntl.LOCK_EX | fcntl.LOCK_SH):
        _state.on_acquire(site)


def _traced_sleep(secs: float) -> None:
    if _state is not None:
        held = [h for h in _state.held()
                if not any(h.startswith(x) for x in SLEEP_EXEMPT_SITES)]
        if held:
            caller = _caller_site()
            with _state.guard:
                _state.sleep_violations.append((caller, tuple(held)))
    _real_sleep(secs)


def enabled_by_env() -> bool:
    return bool(os.environ.get("TFCHECK_TRACE_LOCKS"))


def install() -> None:
    """Patch lock construction, flock, and sleep.  Idempotent."""
    global _installed, _state
    if _installed:
        return
    _state = _TraceState()
    threading.Lock = _traced_lock_factory  # type: ignore[assignment]
    threading.RLock = _traced_rlock_factory  # type: ignore[assignment]
    if fcntl is not None:
        fcntl.flock = _traced_flock  # type: ignore[assignment]
    time.sleep = _traced_sleep  # type: ignore[assignment]
    _installed = True


def maybe_install() -> bool:
    """Install only when TFCHECK_TRACE_LOCKS is set; returns whether on."""
    if enabled_by_env():
        install()
    return _installed


def uninstall() -> None:
    """Restore the real primitives (already-created traced locks keep
    working — they wrap real locks — but stop recording)."""
    global _installed, _state
    threading.Lock = _real_Lock  # type: ignore[assignment]
    threading.RLock = _real_RLock  # type: ignore[assignment]
    if fcntl is not None and _real_flock is not None:
        fcntl.flock = _real_flock  # type: ignore[assignment]
    time.sleep = _real_sleep  # type: ignore[assignment]
    _installed = False
    _state = None


def is_installed() -> bool:
    return _installed


def report() -> Dict[str, object]:
    """The recorded graph: nodes, edges (with counts + first caller),
    acquisition total, and sleep-under-lock violations."""
    if _state is None:
        return {"enabled": False, "nodes": [], "edges": {},
                "acquisitions": 0, "sleep_violations": []}
    with _state.guard:
        return {
            "enabled": True,
            "nodes": sorted(_state.nodes),
            "edges": {"%s -> %s" % k: {"count": v[0], "first_caller": v[1]}
                      for k, v in sorted(_state.edges.items())},
            "acquisitions": _state.acquisitions,
            "sleep_violations": list(_state.sleep_violations),
        }


def find_cycle() -> Optional[List[str]]:
    """A cycle in the runtime acquisition graph, or None."""
    if _state is None:
        return None
    with _state.guard:
        adj: Dict[str, Set[str]] = {}
        for (a, b) in _state.edges:
            adj.setdefault(a, set()).add(b)
            adj.setdefault(b, set())
    from .lockrules import find_cycle as _static_find
    return _static_find(adj)


def check() -> Dict[str, object]:
    """Assert the recorded order is safe; raises AssertionError otherwise.
    Returns the report for display either way."""
    rep = report()
    cycle = find_cycle()
    if cycle is not None:
        raise AssertionError(
            "tfcheck lock trace: runtime lock-order cycle %s (edges: %s)"
            % (" -> ".join(cycle), rep["edges"]))
    if _state is not None and _state.sleep_violations:
        with _state.guard:
            v = _state.sleep_violations[:10]
        raise AssertionError(
            "tfcheck lock trace: time.sleep while holding bus locks: %s" % v)
    return rep
