"""``durability-ordering``: the write-path orderings crashes actually test.

Three checks, one rule id:

* **checkpoint-before-commit** — a worker-side commit call
  (``self._commit(...)`` / ``self.event_store.commit*(...)``) must be
  dominated by a state-store checkpoint (``put_contexts_delta`` /
  ``put_contexts``), either earlier in the same function or in every
  in-file caller.  This is ARCHITECTURE.md §5's ordering: commit marks an
  event *done*, so its effects must be durable first, or a crash strands a
  committed event with no checkpointed result.

* **fsync-before-rename** — ``os.rename``/``os.replace`` publishes a file
  atomically, but only the *name* is atomic: without an ``os.fsync`` of the
  source earlier in the function, a power cut can publish an empty or torn
  file under the final name.

* **flock-before-truncate** — ``SegmentLog`` ``truncate``/``repair`` chops
  a torn tail, which is only correct when no live writer can be mid-append:
  the call must sit inside the owning flock context (``_plock`` /
  ``_wf_flock`` / ``_flock``), directly or via a helper whose in-file
  callers all hold it.  (PR 4's live-writer chop was exactly this bug.)
  ``remove`` is fenced the same way since the TFB1 framing landed: a
  recreated segment re-applies the writer's *preferred* format, so an
  unfenced remove racing a live appender can flip a file's wire format
  mid-stream (v1 lines fused after a TFB1 magic header, or vice versa).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from .core import (Finding, Rule, SourceFile, call_name, callers_of,
                   walk_no_nested_functions, with_flock_items)

_CHECKPOINT_CALLS = ("put_contexts_delta", "put_contexts", "save_contexts")
_WORKER_COMMITS = ("self._commit", "self.event_store.commit",
                   "self.event_store.commit_partitions")
_SEG_MUTATIONS = ("truncate", "repair", "remove")
#: Receivers whose .truncate() is not a SegmentLog chop (os.truncate on the
#: notify counter, file objects in SegmentLog's own implementation).
_TRUNCATE_EXEMPT_RECEIVERS = ("os", "f", "fd", "fh")
#: Classes that own the segment bytes and repair/truncate as part of their
#: contract (SegmentLog internals); their methods are the primitive, not a
#: call site.
_OWNER_CLASSES = ("SegmentLog",)


def _calls_in_order(fn: ast.AST) -> List[ast.Call]:
    calls = [n for n in walk_no_nested_functions(fn)
             if isinstance(n, ast.Call)]
    calls.sort(key=lambda n: (n.lineno, n.col_offset))
    return calls


def _has_checkpoint_before(fn: ast.AST, line: int) -> bool:
    for n in _calls_in_order(fn):
        if n.lineno >= line:
            break
        name = call_name(n) or ""
        if name.rsplit(".", 1)[-1] in _CHECKPOINT_CALLS:
            return True
    return False


def _has_fsync_before(fn: ast.AST, line: int) -> bool:
    for n in _calls_in_order(fn):
        if n.lineno >= line:
            break
        name = call_name(n) or ""
        if name == "os.fsync" or name.rsplit(".", 1)[-1] == "fsync":
            return True
    return False


def _inside_flock(sf: SourceFile, fn: ast.AST, target: ast.AST) -> bool:
    """Is ``target`` lexically within a flock ``with`` in ``fn``?"""
    found = [False]

    def visit(node: ast.AST, covered: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            now = covered
            if isinstance(child, ast.With) and with_flock_items(child):
                now = True
            if child is target and now:
                found[0] = True
            visit(child, now)

    visit(fn, False)
    return found[0]


class DurabilityOrdering(Rule):
    id = "durability-ordering"
    invariant = ("Checkpoint dominates commit; os.rename/os.replace is "
                 "preceded by an fsync of the source; SegmentLog "
                 "truncate/repair/remove (framing-mutating calls) happens "
                 "under the owning flock.")
    motivation = ("PR 4's torn-tail live-writer chop and §5's "
                  "checkpoint-before-commit ordering: every crash test in "
                  "the suite assumes these hold on every path.")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            for qual, cls, fn in sf.functions():
                for n in walk_no_nested_functions(fn):
                    if not isinstance(n, ast.Call):
                        continue
                    name = call_name(n) or ""
                    self._check_commit(sf, fn, n, name, out)
                    self._check_rename(sf, fn, n, name, out)
                    self._check_truncate(sf, cls, fn, n, name, out)
        return out

    # -- checkpoint-before-commit ------------------------------------------------
    def _check_commit(self, sf: SourceFile, fn: ast.AST, n: ast.Call,
                      name: str, out: List[Finding]) -> None:
        if name not in _WORKER_COMMITS:
            return
        if _has_checkpoint_before(fn, n.lineno):
            return
        # helper pattern (_commit): every in-file caller must checkpoint
        # before calling it
        fname = getattr(fn, "name", "")
        callers = callers_of(sf, fname) if fname else []
        callers = [(cfn, c) for cfn, c in callers if cfn is not fn]
        if callers and all(_has_checkpoint_before(cfn, c.lineno)
                           for cfn, c in callers):
            return
        self._finding(
            sf, n, "%s() is not dominated by a state-store checkpoint "
            "(put_contexts_delta before commit — §5 ordering)" % name, out)

    # -- fsync-before-rename -----------------------------------------------------
    def _check_rename(self, sf: SourceFile, fn: ast.AST, n: ast.Call,
                      name: str, out: List[Finding]) -> None:
        if name not in ("os.rename", "os.replace"):
            return
        if _has_fsync_before(fn, n.lineno):
            return
        self._finding(
            sf, n, "%s() without an fsync of the source earlier in the "
            "function — the rename is atomic, the contents are not" % name,
            out)

    # -- flock-before-truncate ---------------------------------------------------
    def _check_truncate(self, sf: SourceFile, cls: Optional[str],
                        fn: ast.AST, n: ast.Call, name: str,
                        out: List[Finding]) -> None:
        f = n.func
        if not isinstance(f, ast.Attribute) or f.attr not in _SEG_MUTATIONS:
            return
        if f.attr == "remove" and (n.args or n.keywords):
            return  # list.remove(x) / set.remove(x) — SegmentLog.remove()
            # takes no arguments
        recv = (name.rpartition(".")[0] or "").rsplit(".", 1)[-1]
        if recv in _TRUNCATE_EXEMPT_RECEIVERS:
            return
        if cls in _OWNER_CLASSES:
            return
        if _inside_flock(sf, fn, n):
            return
        # helper pattern (_append_clean): bless it when every in-file
        # caller sits inside the flock
        fname = getattr(fn, "name", "")
        callers = callers_of(sf, fname) if fname else []
        callers = [(cfn, c) for cfn, c in callers if cfn is not fn]
        if callers and all(_inside_flock(sf, cfn, c) for cfn, c in callers):
            return
        self._finding(
            sf, n, "SegmentLog %s() outside the owning flock — a live "
            "writer's tail could be chopped, or the recreated segment's "
            "wire format flipped under it (PR 4 bug class)" % f.attr, out)
