"""``fencing``: owner-side segment mutations must validate the lease epoch.

In any class that defines ``_check_lease`` (the lease-fenced durable store),
a method that appends to an *owner-side* segment — the committed ledger
(``.com``) or the quarantine ledger (``.dlq``), via ``_append_clean`` or a
direct ``.append()`` — must call ``self._check_lease(...)`` earlier in the
same method.  The event log (``.log``) is exempt: any process may publish;
only consume/commit/quarantine/redrive belong to the lease holder.

This is PR 8's zombie-writer defense: a SIGKILLed-but-not-dead owner whose
lease was superseded must get ``FencedWrite``, never an interleaved append.
A new owner-side write path that skips the check silently reintroduces the
zombie window — exactly the kind of path a reviewer misses and this rule
cannot.
"""
from __future__ import annotations

import ast
from typing import List, Sequence

from .core import (Finding, Rule, SourceFile, call_name, dotted_name,
                   walk_no_nested_functions)

_OWNER_SEGMENTS = ("com", "dlq")


def _owner_segment_of(call: ast.Call) -> str:
    """'com'/'dlq' when the call appends to an owner-side segment, else ''."""
    f = call.func
    name = call_name(call) or ""
    # self._append_clean(fp.com, ...) / self._append_clean(self.dlq, ...)
    if name.rsplit(".", 1)[-1] == "_append_clean" and call.args:
        seg = dotted_name(call.args[0]) or ""
        attr = seg.rsplit(".", 1)[-1]
        if attr in _OWNER_SEGMENTS:
            return attr
    # fp.com.append(...) / self.dlq.append(...)
    if isinstance(f, ast.Attribute) and f.attr == "append":
        recv = dotted_name(f.value) or ""
        attr = recv.rsplit(".", 1)[-1]
        if attr in _OWNER_SEGMENTS:
            return attr
    return ""


class Fencing(Rule):
    id = "fencing"
    invariant = ("In a class defining _check_lease, any append to a .com or "
                 ".dlq segment is preceded by self._check_lease() in the "
                 "same method.")
    motivation = ("PR 8's lease fencing: a stale owner must raise "
                  "FencedWrite, never interleave; an unfenced owner-side "
                  "write path reopens the zombie-writer window.")

    def check(self, files: Sequence[SourceFile]) -> List[Finding]:
        out: List[Finding] = []
        for sf in files:
            fenced_classes = {
                cls for _, cls, fn in sf.functions()
                if cls is not None and fn.name == "_check_lease"}
            if not fenced_classes:
                continue
            for qual, cls, fn in sf.functions():
                if cls not in fenced_classes or fn.name == "_check_lease":
                    continue
                calls = [n for n in walk_no_nested_functions(fn)
                         if isinstance(n, ast.Call)]
                calls.sort(key=lambda n: (n.lineno, n.col_offset))
                checked_line = None
                for n in calls:
                    name = call_name(n) or ""
                    if name.rsplit(".", 1)[-1] == "_check_lease":
                        checked_line = n.lineno
                        continue
                    seg = _owner_segment_of(n)
                    if not seg:
                        continue
                    if checked_line is None or checked_line > n.lineno:
                        self._finding(
                            sf, n, "append to owner-side .%s segment without "
                            "a preceding self._check_lease() — unfenced "
                            "write path (PR 8 invariant)" % seg, out)
        return out
