from .hlo_analysis import collective_bytes, roofline_terms
from .sharding import Resolver, replicated, shardings_for
