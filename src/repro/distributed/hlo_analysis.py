"""Roofline-term extraction from compiled SPMD executables.

``cost_analysis()`` provides per-device HLO FLOPs and bytes accessed.
Collective traffic is NOT in cost_analysis, so we parse the post-SPMD HLO
text and sum operand bytes of every collective op, weighted by its on-wire
cost on a ring: all-reduce ≈ 2×size (reduce-scatter + all-gather phases),
all-gather / reduce-scatter / all-to-all / collective-permute ≈ 1×size.

Collectives inside ``while`` loop bodies (scanned layer stacks!) execute
trip-count times; we multiply ops found in a loop body computation by the
loop's trip count, recovered from the canonical XLA counter pattern.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)(?!-done)\b")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(r"while\(.*\).*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"trip_count=(\d+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Returns per-collective-kind on-wire bytes (per device) + totals."""
    # 1) find trip counts for while bodies
    trip_counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if " while(" in line and "body=" in line:
            m = _WHILE_RE.search(line)
            t = _TRIP_RE.search(line)
            if m:
                trip_counts[m.group(1)] = int(t.group(1)) if t else 1
    # 2) walk computations, accumulating collectives weighted by trip count
    current_comp = None
    comp_ops: Dict[str, list] = {}
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            current_comp = mc.group(1)
            comp_ops.setdefault(current_comp, [])
            continue
        mo = _OP_RE.match(line)
        if mo and current_comp is not None:
            shape_str, kind = mo.group(1), mo.group(2)
            kind = kind.replace("-start", "")
            comp_ops[current_comp].append((kind, _shape_bytes(shape_str)))

    totals: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for comp, ops in comp_ops.items():
        mult = trip_counts.get(comp, 1)
        for kind, nbytes in ops:
            totals[kind] += _WIRE_FACTOR[kind] * nbytes * mult
            counts[kind] += mult
    out = {f"bytes_{k}": v for k, v in totals.items()}
    out.update({f"count_{k}": counts[k] for k in _COLLECTIVES})
    out["bytes_total"] = sum(totals.values())
    return out


# TPU v5e hardware model (per chip) — see the brief.
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (~ per-chip injection, 1 link)


def roofline_terms(cost: dict, coll: dict, n_devices: int) -> Dict[str, float]:
    """cost = compiled.cost_analysis() (per-device); coll = collective_bytes().

    Returns the three roofline terms in seconds (per device)."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("bytes_total", 0.0))
    return {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_accessed / HBM_BW,
        "t_collective": cbytes / ICI_BW,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": cbytes,
    }
