"""Logical-axis sharding rules (MaxText-style), resolved per tensor.

Mesh axes: ``('data','model')`` single-pod, ``('pod','data','model')``
multi-pod.  'pod' + 'data' carry data parallelism + FSDP; 'model' carries
tensor/expert parallelism (heads, ffn, vocab, experts) and optional
activation sequence-sharding (sequence parallelism between blocks).

Resolution is *shape-aware*: a mesh axis is applied to a dim only when the dim
is divisible by the axis size (e.g. granite's single KV head or llama3.2's 24
heads simply stay replicated on a 16-way model axis instead of failing).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Param, is_param
from repro.models.model import ModelConfig

# logical axis -> preferred mesh axes (in priority order per logical axis)
def default_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rules: Dict[str, Any] = {
        # activations
        "batch": data_axes,
        "seq": (),
        "act_seq": ("model",) if cfg.seq_shard_activations else (),
        # params
        "embed": ("data",),        # FSDP dim
        "embed2": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head": (),
        "ffn": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "layers": (),
        # caches
        "seq_kv": (),
    }
    return rules


class Resolver:
    """Callable: (logical axes tuple, shape) -> PartitionSpec (or None)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 overrides: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh = mesh
        self.rules = default_rules(cfg, mesh)
        if overrides:
            self.rules.update(overrides)
        self.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        out = []
        used = set()
        for name, dim in zip(axes, shape):
            mesh_axes = self.rules.get(name, ()) if name else ()
            applied = []
            size = 1
            for ma in mesh_axes:
                if ma in used or ma not in self.sizes:
                    continue
                s = self.sizes[ma]
                if dim % (size * s) == 0:
                    applied.append(ma)
                    size *= s
            used.update(applied)
            if not applied:
                out.append(None)
            elif len(applied) == 1:
                out.append(applied[0])
            else:
                out.append(tuple(applied))
        return P(*out)

    def sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    # activation resolver protocol for layers.lsc
    def __call__(self, axes, shape):
        if len(axes) != len(shape):
            axes = tuple(axes) + (None,) * (len(shape) - len(axes))
        return self.sharding(axes, shape)


def shardings_for(tree_boxed, resolver: Resolver):
    """Boxed (Param) shape tree -> matching NamedSharding tree (unboxed)."""

    def one(p: Param):
        val = p.value
        shape = val.shape if hasattr(val, "shape") else ()
        return resolver.sharding(p.axes, shape)

    return jax.tree_util.tree_map(one, tree_boxed, is_leaf=is_param)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def tree_replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(lambda _: replicated(mesh), tree)
