"""Gradient/delta compression for cross-node exchange (large-scale posture:
FL clients and async-DP workers ship int8-quantized updates — 4× wire/store
reduction vs fp32).

Symmetric per-tensor int8 quantization with a stochastic-rounding option
(unbiased in expectation, the standard trick to keep SGD convergent under
aggressive quantization).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np


def quantize_int8(x: np.ndarray, rng: Optional[np.random.Generator] = None
                  ) -> Dict[str, Any]:
    x = np.asarray(x, np.float32)
    scale = float(np.max(np.abs(x))) / 127.0 if x.size else 0.0
    if scale == 0.0:
        return {"q": np.zeros(x.shape, np.int8), "scale": 0.0,
                "shape": list(x.shape)}
    y = x / scale
    if rng is not None:  # stochastic rounding: unbiased
        low = np.floor(y)
        y = low + (rng.random(y.shape) < (y - low))
    else:
        y = np.rint(y)
    return {"q": np.clip(y, -127, 127).astype(np.int8), "scale": scale,
            "shape": list(x.shape)}


def dequantize_int8(packed: Dict[str, Any]) -> np.ndarray:
    return packed["q"].astype(np.float32) * packed["scale"]


def compressed_bytes(packed: Dict[str, Any]) -> int:
    return int(np.asarray(packed["q"]).nbytes) + 8  # payload + scale


def compress_delta(new: np.ndarray, base: np.ndarray,
                   rng: Optional[np.random.Generator] = None) -> Dict[str, Any]:
    """Quantize the *difference* from the base model (deltas are small and
    centred — much friendlier to int8 than raw weights)."""
    return quantize_int8(np.asarray(new, np.float32)
                         - np.asarray(base, np.float32), rng)


def apply_delta(base: np.ndarray, packed: Dict[str, Any]) -> np.ndarray:
    return np.asarray(base, np.float32) + dequantize_int8(packed)
