"""Jitted public wrapper: [B,S,H,D] layout in, GQA folded for the kernel."""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q [B,S,Hq,D], k/v [B,S,Hkv,Dv] → [B,S,Hq,Dv]."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    # fold batch×head with head-major inner order so kv index math is b//G
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dv)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return out.reshape(B, Hq, S, Dv).transpose(0, 2, 1, 3)
