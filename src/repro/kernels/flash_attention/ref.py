"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def naive_attention(q, k, v, causal: bool = True):
    """q [B,S,Hq,D], k/v [B,S,Hkv,Dv].  O(S²) reference."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v)
    return out.reshape(B, S, Hq, v.shape[-1])
