"""Pallas TPU flash attention (causal, GQA) — the data-plane compute hot spot.

Grid = (batch·q_heads, q_blocks, kv_blocks); the kv axis is the innermost
("arbitrary") dimension so the online-softmax state (m, l, acc) lives in VMEM
scratch across kv steps.  BlockSpecs tile Q/K/V into VMEM: q [block_q, D],
k/v [block_k, D] — MXU-aligned multiples of 128.  Fully-masked causal blocks
are skipped with ``pl.when`` (the triangular schedule).  GQA is handled in the
K/V index maps: query head h reads kv head h // group_size, so no K/V
repetition ever materializes.

Validated in interpret mode against ``ref.naive_attention`` (CPU container;
TPU is the target, see DESIGN.md §6).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  nk: int, seq_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # triangular schedule: skip blocks strictly above the causal diagonal
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # [bq, D]
        k = k_ref[0].astype(jnp.float32)                      # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < seq_len
        if causal:
            mask &= rows >= cols
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)                      # [bk, Dv]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q [BH, S, D]; k/v [BHkv, S, D] with BH = G·BHkv (same batch order).

    Returns [BH, S, Dv]."""
    BH, S, D = q.shape
    BHkv = k.shape[0]
    Dv = v.shape[-1]
    G = BH // BHkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    Sp = nq * block_q
    Skp = nk * block_k
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    if Skp != S:
        k = jnp.pad(k, ((0, 0), (0, Skp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - S), (0, 0)))
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, nk=nk, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
