"""Pure-jnp oracle for the SSD kernel: the plain time recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, Bm, Cm, a):
    """x [BH,S,P], dt [BH,S], Bm/Cm [BH,S,N], a [BH].
    h_t = exp(a·dt_t)·h_{t-1} + dt_t·B_t⊗x_t ;  y_t = C_t·h_t"""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(a.astype(f32) * dt_t)                      # [BH]
        h = da[:, None, None] * h + jnp.einsum(
            "b,bn,bp->bnp", dt_t, b_t.astype(f32), x_t.astype(f32))
        y = jnp.einsum("bn,bnp->bp", c_t.astype(f32), h)
        return h, y

    h0 = jnp.zeros((BH, N, P), f32)
    hT, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2), dt.astype(f32).transpose(1, 0),
         Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype), hT
