"""Fused Pallas TPU kernel for the Mamba2 SSD chunked scan.

Motivated directly by the §Perf cell-A hillclimb: every high-level lever
(remat policy, chunk size, decay dtype) failed to move the memory term
because the [Q,Q] decay tile, the [Q,N] B/C tiles and the [Q,P] gated-input
tiles each round-trip HBM per elementwise op in the XLA path.  Here one grid
step computes a whole chunk *in VMEM*: cumulative decays, the masked decay
tile, the G=C·Bᵀ tile and the running [N,P] state never leave the core.

Grid = (B·H, n_chunks); the chunk axis is sequential ("arbitrary") so the
inter-chunk state lives in VMEM scratch.  Per-head inputs:
    x  [BH, S, P]   gated inputs (already conv'd + silu'd)
    dt [BH, S]      softplus'd step sizes
    Bm [BH, S, N]   input projections  (per-head copies of the shared B)
    Cm [BH, S, N]   output projections
    a  [BH]         per-head decay rate (negative)
Outputs: y [BH, S, P], final state [BH, N, P].

Recurrence (identical discretization to ``repro.models.ssm``):
    h_t = exp(a·dt_t)·h_{t-1} + dt_t·B_t⊗x_t ;  y_t = C_t·h_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_ref,
                h_scr, *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    Bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, N]
    a = a_ref[0].astype(jnp.float32)          # scalar

    la = dt * a                               # log decay per step
    L = jnp.cumsum(la)                        # [Q]
    # intra-chunk: y_i += Σ_{j<=i} (C_i·B_j)·exp(L_i-L_j)·dt_j·x_j
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q,Q]
    decay = jnp.exp(L[:, None] - L[None, :])
    Q = chunk
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    att = jnp.where(rows >= cols, G * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q,P]
    # inter-chunk: y_i += exp(L_i)·(C_i·h_in)
    h = h_scr[...]                            # [N,P]
    y = y + jnp.exp(L)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # state update: h_out = exp(L_last)·h_in + Σ_j exp(L_last-L_j)·dt_j·B_j⊗x_j
    w = jnp.exp(L[-1] - L) * dt               # [Q]
    h_new = jnp.exp(L[-1]) * h + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [N,P]
    h_scr[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        state_ref[0] = h_new.astype(state_ref.dtype)


def ssd_scan(x, dt, Bm, Cm, a, *, chunk: int = 128, interpret: bool = False):
    """x [BH,S,P], dt [BH,S], Bm/Cm [BH,S,N], a [BH] →
    (y [BH,S,P], state [BH,N,P])."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        # neutral padding: dt=0 ⇒ decay 1, zero state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    kernel = functools.partial(_ssd_kernel, chunk=Q, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q), lambda b, c: (b, c)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc * Q, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, Bm, Cm, a)
    return y[:, :S], state
