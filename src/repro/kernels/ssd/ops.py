"""Jitted wrapper: [B,S,H,P] model layout → fused SSD kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, Bm, Cm, a, chunk: int = 128, interpret: bool = False):
    """x [B,S,H,P], dt [B,S,H], Bm/Cm [B,S,N] (shared across heads),
    a [H] → (y [B,S,H,P], state [B,H,N,P])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Bf = jnp.repeat(Bm[:, None], H, axis=1).reshape(B * H, S, N)
    Cf = jnp.repeat(Cm[:, None], H, axis=1).reshape(B * H, S, N)
    af = jnp.tile(a, B)
    y, state = ssd_scan(xf, dtf, Bf, Cf, af, chunk=chunk, interpret=interpret)
    return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
            state.reshape(B, H, N, P))
