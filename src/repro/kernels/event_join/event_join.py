"""Pallas TPU kernel for the paper's hot loop: composite-event join
aggregation (Table 1 "Join": 100 triggers × 2000 events each).

TPU-native adaptation (DESIGN.md §2): instead of a per-event Python
interpreter, a *batch* of routed events is reduced to per-trigger activation
counts via a one-hot segmented sum on the VPU, then compared against each
trigger's threshold.  Grid tiles the event stream into VMEM blocks of
``block_events``; per-trigger counts accumulate in VMEM scratch across the
(sequential) grid and fire flags are emitted on the last step.

Inputs:  events   [N]  int32 trigger ids (−1 = padding)
         counts   [T]  int32 current per-trigger counts (context state)
         expected [T]  int32 per-trigger thresholds
Outputs: new_counts [T] int32, fired [T] int32 (0/1)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _join_kernel(events_ref, counts_ref, expected_ref, new_counts_ref,
                 fired_ref, acc_scr, *, n_blocks: int, block_events: int,
                 n_triggers: int):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ev = events_ref[...]                                   # [block_events]
    # one-hot segmented count: [block, T] compare on the VPU, reduce rows
    tids = jax.lax.broadcasted_iota(jnp.int32, (block_events, n_triggers), 1)
    onehot = (ev[:, None] == tids).astype(jnp.int32)
    acc_scr[...] = acc_scr[...] + onehot.sum(axis=0)

    @pl.when(ib == n_blocks - 1)
    def _finish():
        total = counts_ref[...] + acc_scr[...]
        new_counts_ref[...] = total
        fired_ref[...] = (total >= expected_ref[...]).astype(jnp.int32)


def event_join_counts(events, counts, expected, *, block_events: int = 1024,
                      interpret: bool = False):
    (N,) = events.shape
    (T,) = counts.shape
    block = min(block_events, N)
    nb = -(-N // block)
    if nb * block != N:
        events = jnp.pad(events, (0, nb * block - N), constant_values=-1)
    kernel = functools.partial(_join_kernel, n_blocks=nb, block_events=block,
                               n_triggers=T)
    new_counts, fired = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((T,), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(events, counts, expected)
    return new_counts, fired
