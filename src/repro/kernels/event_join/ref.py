"""Pure-jnp oracle for the event-join kernel."""
from __future__ import annotations

import jax.numpy as jnp


def join_counts_ref(events, counts, expected):
    """events [N] int32 (−1 padding), counts/expected [T] int32."""
    T = counts.shape[0]
    valid = events >= 0
    add = jnp.zeros((T,), jnp.int32).at[jnp.where(valid, events, 0)].add(
        valid.astype(jnp.int32))
    new_counts = counts + add
    return new_counts, (new_counts >= expected).astype(jnp.int32)
