"""Backend dispatch for the event-join segmented sum.

The worker's batch plane reduces a routed event batch to per-trigger
activation counts (``new_counts``) and threshold-crossing flags (``fired``).
Three interchangeable backends compute the same one-hot segmented sum:

* ``pallas`` — the TPU kernel (``event_join.event_join_counts``), used when a
  TPU is attached;
* ``jax``    — the jitted pure-jnp oracle (``ref.join_counts_ref``), the
  CPU/GPU XLA path;
* ``numpy``  — ``np.bincount``, dependency-light fallback when JAX is absent
  (or for tiny batches where XLA dispatch overhead dominates).

``join_counts(events, counts, expected)`` takes int32 numpy arrays
(``events`` holds trigger row ids, −1 = padding) and returns numpy
``(new_counts, fired)``.  Selection: ``TRIGGERFLOW_JOIN_BACKEND`` env var
(``auto`` | ``numpy`` | ``jax`` | ``pallas`` | ``off``), default ``auto`` =
pallas on TPU, numpy otherwise (measured faster than XLA dispatch for the
≤4k-event batches the worker consumes).
"""
from __future__ import annotations

import os
from typing import Callable, Optional, Tuple

import numpy as np

JoinFn = Callable[[np.ndarray, np.ndarray, np.ndarray],
                  Tuple[np.ndarray, np.ndarray]]


def _numpy_join(events: np.ndarray, counts: np.ndarray,
                expected: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    valid = events[events >= 0]
    add = np.bincount(valid, minlength=counts.shape[0]).astype(np.int32)
    new_counts = counts + add
    return new_counts, (new_counts >= expected).astype(np.int32)


def _make_jax_join() -> JoinFn:
    import jax

    from .ref import join_counts_ref

    f = jax.jit(join_counts_ref)

    def run(events, counts, expected):
        nc, fired = f(events, counts, expected)
        return np.asarray(nc), np.asarray(fired)

    return run


def _make_pallas_join() -> JoinFn:
    from .ops import event_join

    def run(events, counts, expected):
        nc, fired = event_join(events, counts, expected)
        return np.asarray(nc), np.asarray(fired)

    return run


def _on_tpu() -> bool:
    # Only consult jax if something else already paid its import cost:
    # importing (and device-initializing) jax here would add seconds to
    # worker startup on CPU-only hosts just to learn there is no TPU.
    import sys

    jax = sys.modules.get("jax")
    if jax is None:
        return False
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # noqa: BLE001 - not initializable
        return False


_resolved: dict = {}


def resolve_join_backend(name: Optional[str] = None) -> Tuple[str, Optional[JoinFn]]:
    """Resolve a backend name to ``(resolved_name, fn)``, cached per name.

    ``fn`` is ``None`` for ``off``.  Unavailable explicit choices raise so
    misconfiguration is loud; ``auto`` silently degrades to numpy."""
    name = (name or os.environ.get("TRIGGERFLOW_JOIN_BACKEND", "auto")).lower()
    cached = _resolved.get(name)
    if cached is not None:
        return cached
    if name == "off":
        resolved: Tuple[str, Optional[JoinFn]] = ("off", None)
    elif name == "numpy":
        resolved = ("numpy", _numpy_join)
    elif name == "jax":
        resolved = ("jax", _make_jax_join())
    elif name == "pallas":
        resolved = ("pallas", _make_pallas_join())
    elif name != "auto":
        raise ValueError(f"unknown join backend {name!r}")
    elif _on_tpu():
        resolved = ("pallas", _make_pallas_join())
    else:
        resolved = ("numpy", _numpy_join)
    _resolved[name] = resolved
    return resolved


def join_counts(events: np.ndarray, counts: np.ndarray,
                expected: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Segmented-sum join on the default backend (resolved once, cached by
    ``resolve_join_backend``).  Raises when the backend is ``off`` — callers
    that can degrade (the worker's vector plane) handle that at setup."""
    _name, fn = resolve_join_backend()
    if fn is None:
        raise RuntimeError("join backend disabled (TRIGGERFLOW_JOIN_BACKEND=off)")
    return fn(events, counts, expected)


def join_counts_segments(lens, counts: np.ndarray, expected: np.ndarray,
                         fn: Optional[JoinFn] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Segmented-sum join over *contiguous runs*: ``lens[i]`` events belong
    to trigger row ``i``.  This is the shape the columnar ingest path
    produces (a batch bucketed by subject is runs of row ids, never a
    ragged scatter), so the row-id expansion lives here next to the kernel
    instead of in every caller."""
    if fn is None:
        _name, fn = resolve_join_backend()
        if fn is None:
            raise RuntimeError(
                "join backend disabled (TRIGGERFLOW_JOIN_BACKEND=off)")
    event_rows = np.repeat(np.arange(len(lens), dtype=np.int32), lens)
    return fn(event_rows, counts, expected)
