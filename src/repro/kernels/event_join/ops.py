"""Jitted wrapper for the event-join kernel."""
from __future__ import annotations

from functools import partial

import jax

from .event_join import event_join_counts


@partial(jax.jit, static_argnames=("block_events", "interpret"))
def event_join(events, counts, expected, block_events: int = 1024,
               interpret: bool = False):
    return event_join_counts(events, counts, expected,
                             block_events=block_events, interpret=interpret)
