"""Jitted training / serving step factories.

``make_train_step`` supports gradient-accumulation microbatching (a hillclimb
lever: trades activation memory against step latency) via ``lax.scan`` over
microbatches with fp32 grad accumulation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import Model

from .optimizer import AdamW


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt: AdamW, accum_steps: int = 1):
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            def micro(batch_slice):
                return jax.value_and_grad(loss_fn, has_aux=True)(params, batch_slice)

            def body(carry, batch_slice):
                g_acc, l_acc = carry
                (l, _), g = micro(batch_slice)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            micro_batch = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]),
                batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                                micro_batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {}
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        out_metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                       "step": opt_state["count"]}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(model: Model, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        return model.decode(params, cache, batch)

    return decode_step
