"""AdamW with warmup+cosine schedule and global-norm clipping (no optax
dependency).  Moments are fp32 and shard exactly like their parameters
(ZeRO-3-equivalent under the FSDP rules), params may stay bf16.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return schedule


@dataclasses.dataclass
class AdamW:
    lr: Callable = warmup_cosine(3e-4)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            gnorm = global_norm(grads)
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state["v"], grads)
        bc1 = 1 - self.b1 ** cf
        bc2 = 1 - self.b2 ** cf
        lr = self.lr(count)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            step = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}, gnorm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
