from . import checkpoint
from .data import SyntheticData
from .optimizer import AdamW, global_norm, warmup_cosine
from .train_step import make_decode_step, make_prefill_step, make_train_step
from .trainer import JaxCluster, build_training_workflow, run_training
