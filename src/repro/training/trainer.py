"""Triggerflow-orchestrated training: the training loop *is* an ASF state
machine (the paper's §5.2 engine), with the JAX cluster as the "serverless
function" backend.

    Init ──▶ TrainChunk ──▶ Gate(Choice) ──▶ TrainChunk …
                                   └──▶ Finalize(Succeed)

Each TrainChunk task runs N optimizer steps on the mesh, checkpoints, and
emits a termination event carrying {step, loss}; the Choice trigger loops
until the target step count.  Kill the worker mid-run and restart: Triggerflow
replays uncommitted events while the cluster restores the latest checkpoint —
the two fault-tolerance layers compose (benchmarked in Fig-13 repro).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import Triggerflow
from repro.core.statemachine import StateMachine
from repro.models import Model, ModelConfig, unbox

from . import checkpoint as ckpt_lib
from .data import SyntheticData
from .optimizer import AdamW, warmup_cosine
from .train_step import make_train_step


class JaxCluster:
    """Host-side training executor (the data plane the triggers orchestrate)."""

    def __init__(self, cfg: ModelConfig, workdir: str, batch: int, seq: int,
                 peak_lr: float = 3e-4, total_steps: int = 1000,
                 data_kind: str = "copy_task", seed: int = 0,
                 accum_steps: int = 1):
        self.cfg = cfg
        self.workdir = workdir
        self.model = Model(cfg)
        self.opt = AdamW(lr=warmup_cosine(peak_lr, warmup=20, total=total_steps))
        self.data = SyntheticData(cfg.vocab, seq, batch, kind=data_kind, seed=seed,
                                  codebooks=cfg.codebooks)
        self.step_fn = jax.jit(make_train_step(self.model, self.opt,
                                               accum_steps=accum_steps))
        self.step = 0
        self.params = None
        self.opt_state = None
        self.history: list = []

    # -- state ------------------------------------------------------------------
    def ensure_state(self) -> None:
        if self.params is not None:
            return
        params = unbox(self.model.init(jax.random.PRNGKey(0)))
        opt_state = self.opt.init(params)
        latest = ckpt_lib.latest_step(self.workdir)
        if latest is not None:
            self.step, self.params, self.opt_state, meta = ckpt_lib.restore(
                self.workdir, params, opt_state)
        else:
            self.params, self.opt_state = params, opt_state

    # -- the "serverless function" ------------------------------------------------
    def train_chunk(self, args: Dict[str, Any]) -> Dict[str, Any]:
        self.ensure_state()
        n = int(args.get("steps", 10))
        losses = []
        t0 = time.time()
        for _ in range(n):
            batch = self.data.batch_at(self.step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            losses.append(float(metrics["loss"]))
        ckpt_lib.save(self.workdir, self.step, self.params, self.opt_state,
                      extra={"loss": losses[-1]})
        rec = {"step": self.step, "loss": losses[-1],
               "loss_mean": float(np.mean(losses)),
               "wall_s": round(time.time() - t0, 3)}
        self.history.append(rec)
        return rec

    def evaluate(self, args: Dict[str, Any]) -> Dict[str, Any]:
        self.ensure_state()
        batch = self.data.batch_at(10 ** 6 + self.step)  # held-out stream
        loss, _ = jax.jit(self.model.loss)(self.params, batch)
        return {"step": self.step, "eval_loss": float(loss)}


def build_training_workflow(tf: Triggerflow, cluster: JaxCluster, workflow: str,
                            total_steps: int, chunk_steps: int = 10,
                            eval_every_chunks: int = 0) -> StateMachine:
    """Compile the training loop to an ASF state machine over triggers."""
    tf.backend.register(f"{workflow}:train_chunk",
                        lambda args: cluster.train_chunk(
                            {**(args if isinstance(args, dict) else {}),
                             "steps": chunk_steps}))
    tf.backend.register(f"{workflow}:evaluate", cluster.evaluate)
    defn = {
        "StartAt": "TrainChunk",
        "States": {
            "TrainChunk": {"Type": "Task", "Resource": f"{workflow}:train_chunk",
                           "Next": "Gate"},
            "Gate": {"Type": "Choice",
                     "Choices": [{"Variable": "$.result.step", "Op": "lt",
                                  "Value": total_steps, "Next": "TrainChunk"}],
                     "Default": "Eval" if eval_every_chunks else "Done"},
            "Done": {"Type": "Succeed"},
        },
    }
    if eval_every_chunks:
        defn["States"]["Eval"] = {"Type": "Task",
                                  "Resource": f"{workflow}:evaluate",
                                  "Next": "Done"}
    sm = StateMachine(defn)
    sm.deploy(tf, workflow)
    return sm


def run_training(cfg: ModelConfig, workdir: str, total_steps: int = 50,
                 chunk_steps: int = 10, batch: int = 8, seq: int = 128,
                 tf: Optional[Triggerflow] = None, peak_lr: float = 3e-4,
                 timeout: float = 3600.0) -> Dict[str, Any]:
    """End-to-end: trigger-orchestrated training run.  Returns final state."""
    tf = tf or Triggerflow(inline_functions=True)
    cluster = JaxCluster(cfg, workdir, batch, seq, peak_lr=peak_lr,
                         total_steps=total_steps)
    wf = f"train-{cfg.arch}-{os.path.basename(workdir)}"
    sm = build_training_workflow(tf, cluster, wf, total_steps, chunk_steps,
                                 eval_every_chunks=1)
    result = sm.run(tf, wf, timeout=timeout)
    return {"workflow_result": result, "history": cluster.history,
            "cluster": cluster}
