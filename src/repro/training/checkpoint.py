"""Sharded-tree checkpointing to local disk (orbax-free, atomic).

Leaves are saved as one ``.npz`` per checkpoint with path-derived keys;
``save`` writes to a temp dir and renames, so a crash mid-save never corrupts
the latest checkpoint — this is the state-level half of the fault-tolerance
story (Triggerflow's event replay is the workflow-level half; Fig 13 repro
exercises both together).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[^\w.]", "", str(p)) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bfloat16 → store as fp32, dtype restored on load
            arr = np.asarray(jax.numpy.asarray(leaf).astype(jax.numpy.float32))
        flat[key] = arr
    return flat


def save(path: str, step: int, params, opt_state=None, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f".tmp-{step}")
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    ckpts = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def restore(path: str, params_like, opt_like=None,
            step: Optional[int] = None) -> Tuple[int, Any, Any, dict]:
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")

    def unflatten(npz, like):
        flat = dict(np.load(npz))
        leaves, treedef = jax.tree_util.tree_flatten(like)
        paths = [
            "/".join(re.sub(r"[^\w.]", "", str(p)) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
        ]
        new_leaves = [jax.numpy.asarray(flat[k]).astype(l.dtype)
                      for k, l in zip(paths, leaves)]
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    params = unflatten(os.path.join(d, "params.npz"), params_like)
    opt_state = None
    if opt_like is not None and os.path.exists(os.path.join(d, "opt_state.npz")):
        opt_state = unflatten(os.path.join(d, "opt_state.npz"), opt_like)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    return step, params, opt_state, meta
