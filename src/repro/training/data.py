"""Deterministic synthetic data pipelines (no external datasets offline).

* ``zipf_lm``  — Zipf-distributed token stream (realistic vocab statistics).
* ``copy_task`` — second half of each sequence repeats the first half; a real
  learnable task, so the end-to-end example's loss visibly drops toward the
  copy-entropy floor instead of staying at ln(V).

Batches are seeded per-step, so a restarted run (fault-tolerance benchmark)
regenerates the identical stream — the data-pipeline analogue of event replay.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticData:
    def __init__(self, vocab: int, seq: int, batch: int, kind: str = "copy_task",
                 seed: int = 0, codebooks: int = 0):
        assert kind in ("zipf_lm", "copy_task")
        self.vocab = vocab
        self.seq = seq
        self.batch = batch
        self.kind = kind
        self.seed = seed
        self.codebooks = codebooks

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        shape = ((self.batch, self.codebooks, self.seq) if self.codebooks
                 else (self.batch, self.seq))
        if self.kind == "zipf_lm":
            ranks = rng.zipf(1.3, size=shape).astype(np.int64)
            tokens = np.minimum(ranks, self.vocab - 1).astype(np.int32)
        else:
            half = self.seq // 2
            first = rng.integers(0, self.vocab, size=shape[:-1] + (half,),
                                 dtype=np.int32)
            tokens = np.concatenate([first, first], axis=-1)
            if tokens.shape[-1] < self.seq:
                pad = rng.integers(0, self.vocab,
                                   size=shape[:-1] + (self.seq - tokens.shape[-1],),
                                   dtype=np.int32)
                tokens = np.concatenate([tokens, pad], axis=-1)
        targets = np.concatenate(
            [tokens[..., 1:], np.full(shape[:-1] + (1,), -1, np.int32)], axis=-1)
        if self.kind == "copy_task":
            # only score the learnable (copied) second half
            half = self.seq // 2
            masked = targets.copy()
            masked[..., : half - 1] = -1
            targets = masked
        return {"tokens": tokens, "targets": targets}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
