"""Shared model plumbing: boxed parameters carrying logical sharding axes.

Every parameter is created as ``Param(value, axes)`` where ``axes`` is a tuple
of *logical* axis names (one per dim, ``None`` = unsharded).  The distributed
layer resolves logical axes → mesh axes (MaxText-style rules).  ``Param`` is a
pytree with ``axes`` as static aux data, so ``jax.eval_shape`` over an init
function yields the parameter *shapes and axes* without allocating — which is
exactly what the multi-pod dry-run needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    """Boxed params → plain arrays."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def axes_tree(tree):
    """Boxed params → logical-axes pytree (same structure as ``unbox``)."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)


# -- initializers -----------------------------------------------------------------
def make_param(key, shape, axes, scale: Optional[float] = None,
               dtype=jnp.bfloat16, init: str = "normal") -> Param:
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            scale = shape[0] ** -0.5  # fan-in on dim 0 by convention
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Param(v, tuple(axes))


class KeyGen:
    """Deterministic key splitter so init functions stay tidy."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
