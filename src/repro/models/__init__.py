from .common import KeyGen, Param, axes_tree, is_param, make_param, unbox
from .model import Model, ModelConfig

__all__ = ["KeyGen", "Model", "ModelConfig", "Param", "axes_tree", "is_param",
           "make_param", "unbox"]
