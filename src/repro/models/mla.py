"""Multi-head Latent Attention (DeepSeek-V2): the KV cache stores only the
compressed latent ``c_kv`` [B,S,kv_lora] plus the shared decoupled RoPE key
[B,S,rope_dim] — a ~10-50× cache reduction vs full K/V.  Decode uses the
*absorbed* formulation (W_uk folded into the query, W_uv applied after the
latent-space attention), so decode FLOPs/bytes scale with kv_lora, not H×dh.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import KeyGen, make_param
from .layers import apply_rope, attention_chunked, lsc, rms_norm, rms_norm_init, rope_angles


def mla_init(keys: KeyGen, d_model: int, n_heads: int, q_lora: int, kv_lora: int,
             nope_dim: int = 128, rope_dim: int = 64, v_dim: int = 128):
    p = {
        "wdq": make_param(keys(), (d_model, q_lora), ("embed", None), scale=d_model ** -0.5),
        "q_norm": rms_norm_init(keys(), q_lora),
        "wuq": make_param(keys(), (q_lora, n_heads, nope_dim + rope_dim),
                          (None, "heads", "head"), scale=q_lora ** -0.5),
        "wdkv": make_param(keys(), (d_model, kv_lora), ("embed", None), scale=d_model ** -0.5),
        "kv_norm": rms_norm_init(keys(), kv_lora),
        "wuk": make_param(keys(), (kv_lora, n_heads, nope_dim),
                          (None, "heads", "head"), scale=kv_lora ** -0.5),
        "wuv": make_param(keys(), (kv_lora, n_heads, v_dim),
                          (None, "heads", "head"), scale=kv_lora ** -0.5),
        "wkr": make_param(keys(), (d_model, rope_dim), ("embed", None), scale=d_model ** -0.5),
        "wo": make_param(keys(), (n_heads, v_dim, d_model), ("heads", "head", "embed"),
                         scale=(n_heads * v_dim) ** -0.5),
    }
    return p


def _queries(params, x, cos, sin, nope_dim):
    cq = rms_norm(params["q_norm"], x @ params["wdq"])
    q = jnp.einsum("bsq,qhk->bshk", cq, params["wuq"])
    qn, qr = q[..., :nope_dim], q[..., nope_dim:]
    qr = apply_rope(qr, cos, sin)
    return qn, qr


def mla_forward(params, x, positions, nope_dim=128, rope_dim=64,
                rope_theta=10000.0, q_chunk=2048, kv_chunk=2048, return_cache=False,
                unroll=False):
    """Training/prefill path: expand the latent into full K/V per head."""
    B, S, D = x.shape
    cos, sin = rope_angles(positions, rope_dim, rope_theta)
    qn, qr = _queries(params, x, cos, sin, nope_dim)
    ckv = rms_norm(params["kv_norm"], x @ params["wdkv"])           # [B,S,kvl]
    kr = apply_rope((x @ params["wkr"])[:, :, None, :], cos, sin)   # [B,S,1,rope]
    kn = jnp.einsum("bsc,chk->bshk", ckv, params["wuk"])
    v = jnp.einsum("bsc,chk->bshk", ckv, params["wuv"])
    H = kn.shape[2]
    q = jnp.concatenate([qn, qr], -1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, (B, S, H, kr.shape[-1]))], -1)
    q = lsc(q, "batch", "seq", "heads", None)
    k = lsc(k, "batch", "seq", "heads", None)
    attn = attention_chunked(q, k, v, causal=True, q_chunk=q_chunk,
                             kv_chunk=kv_chunk, unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", attn, params["wo"])
    if return_cache:
        return out, (ckv, kr[:, :, 0, :])
    return out


def mla_decode(params, x, cache_ckv, cache_kr, pos, nope_dim=128, rope_dim=64,
               rope_theta=10000.0):
    """Absorbed decode: score/context in the kv_lora latent space.
    x [B,1,D]; cache_ckv [B,T,kvl]; cache_kr [B,T,rope]."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = rope_angles(positions, rope_dim, rope_theta)
    qn, qr = _queries(params, x, cos, sin, nope_dim)                # [B,1,H,*]
    ckv_t = rms_norm(params["kv_norm"], x @ params["wdkv"])         # [B,1,kvl]
    kr_t = apply_rope((x @ params["wkr"])[:, :, None, :], cos, sin)[:, :, 0, :]
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_t.astype(cache_ckv.dtype), pos, 1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_t.astype(cache_kr.dtype), pos, 1)
    # absorb W_uk into the query: q_lat [B,H,kvl]
    q_lat = jnp.einsum("bhk,chk->bhc", qn[:, 0], params["wuk"])
    s = jnp.einsum("bhc,btc->bht", q_lat, cache_ckv).astype(jnp.float32)
    s = s + jnp.einsum("bhk,btk->bht", qr[:, 0], cache_kr).astype(jnp.float32)
    s = s / math.sqrt(nope_dim + rope_dim)
    valid = jnp.arange(cache_ckv.shape[1])[None, :] < (pos + 1)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(cache_ckv.dtype)
    ctx = jnp.einsum("bht,btc->bhc", p, cache_ckv)                  # latent context
    out_v = jnp.einsum("bhc,chk->bhk", ctx, params["wuv"])          # expand to v_dim
    out = jnp.einsum("bhk,hkd->bd", out_v, params["wo"])[:, None, :]
    return out, cache_ckv, cache_kr
