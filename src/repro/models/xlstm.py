"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory with recurrent gate mixing).

mLSTM recurrence per head (state C [Dk,Dv], normalizer n [Dk]):
    C_t = f_t·C_{t-1} + i_t·(k_t ⊗ v_t)
    n_t = f_t·n_{t-1} + i_t·k_t
    y_t = (q_t·C_t) / max(|q_t·n_t|, 1)
Training uses a chunk-parallel form (same algebra as the SSD chunking in
``ssm.py``), verified against the step recurrence by property tests.

Deviation from the paper (recorded in DESIGN.md): the input gate uses
``sigmoid`` rather than ``exp`` so the chunked form is stable in fp32 without
the max-stabilizer bookkeeping; forget gates are sigmoid as in the paper.
sLSTM keeps the paper's per-head block-diagonal recurrent gate mixing but is
evaluated as a plain time scan (it is inherently sequential — the paper
accelerates it with a fused GPU kernel; on TPU we keep the scan in HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, make_param
from .layers import lsc, rms_norm, rms_norm_init


# ---------------------------------------------------------------- mLSTM ----
def mlstm_init(keys: KeyGen, d_model: int, n_heads: int, expand: int = 2):
    di = expand * d_model
    Dh = di // n_heads
    # q/k/v are per-head block-diagonal projections (as in the xLSTM paper's
    # mLSTM cell) — di²/H params each instead of di²
    return {
        "w_up": make_param(keys(), (d_model, 2 * di), ("embed", "ffn"), scale=d_model ** -0.5),
        "wq": make_param(keys(), (n_heads, Dh, Dh), ("heads", None, None), scale=Dh ** -0.5),
        "wk": make_param(keys(), (n_heads, Dh, Dh), ("heads", None, None), scale=Dh ** -0.5),
        "wv": make_param(keys(), (n_heads, Dh, Dh), ("heads", None, None), scale=Dh ** -0.5),
        "wi": make_param(keys(), (di, n_heads), ("ffn", None), scale=di ** -0.5),
        "wf": make_param(keys(), (di, n_heads), ("ffn", None), scale=di ** -0.5),
        "f_bias": make_param(keys(), (n_heads,), (None,), init="ones"),
        "out_norm": rms_norm_init(keys(), di),
        "w_down": make_param(keys(), (di, d_model), ("ffn", "embed"), scale=di ** -0.5),
    }


def _mlstm_chunked(q, k, v, log_f, i_gate, chunk: int):
    """q/k/v [B,S,H,D]; log_f/i_gate [B,S,H].  Returns y, (C_T, n_T)."""
    Bsz, S, H, D = q.shape
    Q = min(chunk, S)
    S0 = S
    if S % Q:
        # neutral padding: f=1 (log_f=0), i=0 ⇒ padded steps are no-ops
        pad = Q - S % Q
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32
    scale = D ** -0.5

    qc = q.reshape(Bsz, nc, Q, H, D).astype(f32) * scale
    kc = k.reshape(Bsz, nc, Q, H, D).astype(f32)
    vc = v.reshape(Bsz, nc, Q, H, D).astype(f32)
    lf = log_f.reshape(Bsz, nc, Q, H).astype(f32)
    ig = i_gate.reshape(Bsz, nc, Q, H).astype(f32)
    L = jnp.cumsum(lf, axis=2)
    Llast = L[:, :, -1]

    G = jnp.einsum("bcihd,bcjhd->bcijh", qc, kc)
    decay = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :])
    ii = jnp.arange(Q)
    mask = (ii[:, None] >= ii[None, :]).astype(f32)
    att = G * decay * mask[None, None, :, :, None] * ig[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhd->bcihd", att, vc)
    # denominator: q_i·n_i — the intra part is just the row-sum of att
    den_diag = att.sum(axis=3)                                    # [b,c,i,h]

    w = jnp.exp(Llast[:, :, None, :] - L) * ig                    # [b,c,q,h]
    csC = jnp.einsum("bcjh,bcjhk,bcjhv->bchkv", w, kc, vc)
    csn = jnp.einsum("bcjh,bcjhk->bchk", w, kc)

    def step(carry, inp):
        C, n = carry
        csC_c, csn_c, dec_c = inp
        prev = (C, n)
        C = dec_c[:, :, None, None] * C + csC_c
        n = dec_c[:, :, None] * n + csn_c
        return (C, n), prev

    C0 = jnp.zeros((Bsz, H, D, D), f32)
    n0 = jnp.zeros((Bsz, H, D), f32)
    (CT, nT), (Cprev, nprev) = jax.lax.scan(
        step, (C0, n0),
        (csC.transpose(1, 0, 2, 3, 4), csn.transpose(1, 0, 2, 3),
         jnp.exp(Llast).transpose(1, 0, 2)))
    Cprev = Cprev.transpose(1, 0, 2, 3, 4)                         # [b,c,h,k,v]
    nprev = nprev.transpose(1, 0, 2, 3)

    eL = jnp.exp(L)
    y_inter = jnp.einsum("bcihk,bchkv,bcih->bcihv", qc, Cprev, eL)
    den_inter = jnp.einsum("bcihk,bchk,bcih->bcih", qc, nprev, eL)
    den = den_diag + den_inter
    y = (y_diag + y_inter) / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.reshape(Bsz, S, H, D)[:, :S0], (CT, nT)


def mlstm_cell_step(q, k, v, log_f, i_gate, C, n):
    """Single step: q/k/v [B,H,D], gates [B,H]."""
    f32 = jnp.float32
    scale = q.shape[-1] ** -0.5
    q, k, v = q.astype(f32) * scale, k.astype(f32), v.astype(f32)
    f = jnp.exp(log_f.astype(f32))
    i = i_gate.astype(f32)
    C = f[:, :, None, None] * C + i[:, :, None, None] * jnp.einsum("bhk,bhv->bhkv", k, v)
    n = f[:, :, None] * n + i[:, :, None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), 1.0)
    return num / den[..., None], C, n


def _mlstm_qkvg(params, xm, n_heads):
    di = xm.shape[-1]
    D = di // n_heads
    xh = xm.reshape(*xm.shape[:-1], n_heads, D)
    q = jnp.einsum("...hd,hde->...he", xh, params["wq"])
    k = jnp.einsum("...hd,hde->...he", xh, params["wk"])
    v = jnp.einsum("...hd,hde->...he", xh, params["wv"])
    log_f = jax.nn.log_sigmoid((xm @ params["wf"]).astype(jnp.float32)
                               + params["f_bias"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid((xm @ params["wi"]).astype(jnp.float32))
    return q, k, v, log_f, i_gate


def mlstm_forward(params, x, n_heads: int, chunk: int = 128, return_state: bool = False):
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    up = lsc(up, "batch", "seq", "ffn")
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_f, i_gate = _mlstm_qkvg(params, xm, n_heads)
    y, state = _mlstm_chunked(q, k, v, log_f, i_gate, chunk)
    y = y.reshape(*xm.shape).astype(x.dtype)
    y = rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, params["w_down"])
    if return_state:
        return out, state
    return out


def mlstm_decode(params, x, state, n_heads: int):
    C, n = state
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v, log_f, i_gate = _mlstm_qkvg(params, xm[:, 0], n_heads)
    y, C, n = mlstm_cell_step(q, k, v, log_f, i_gate, C, n)
    y = y.reshape(xm[:, 0].shape).astype(x.dtype)
    y = rms_norm(params["out_norm"], y) * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bf,fd->bd", y, params["w_down"])[:, None, :]
    return out, (C, n)


# ---------------------------------------------------------------- sLSTM ----
def slstm_init(keys: KeyGen, d_model: int, n_heads: int):
    dh = d_model // n_heads
    return {
        "wx": make_param(keys(), (d_model, 4 * d_model), ("embed", "ffn"),
                         scale=d_model ** -0.5),
        "r": make_param(keys(), (n_heads, dh, 4 * dh), ("heads", None, None),
                        scale=dh ** -0.5),
        "bias": make_param(keys(), (4 * d_model,), ("ffn",), init="zeros"),
        "out_norm": rms_norm_init(keys(), d_model),
        "wo": make_param(keys(), (d_model, d_model), ("embed", "embed2"),
                         scale=d_model ** -0.5),
    }


def slstm_cell_step(gx, r, h, c, n, n_heads):
    """gx [B,4d] (input-projected gates); h/c/n [B,H,dh]."""
    f32 = jnp.float32
    B, H = h.shape[0], n_heads
    dh = h.shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", h, r).reshape(B, 4 * H * dh)
    g = (gx.astype(f32) + rec.astype(f32)).reshape(B, H, dh, 4)
    i = jax.nn.sigmoid(g[..., 0])
    f = jax.nn.sigmoid(g[..., 1] + 1.0)
    z = jnp.tanh(g[..., 2])
    o = jax.nn.sigmoid(g[..., 3])
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return h, c, n


def slstm_forward(params, x, n_heads: int, return_state: bool = False):
    B, S, d = x.shape
    dh = d // n_heads
    gx = jnp.einsum("bsd,de->bse", x, params["wx"]) + params["bias"]
    # regroup so gates interleave per head-dim: [B,S,H,dh,4]
    gx = gx.reshape(B, S, 4, n_heads, dh).transpose(0, 1, 3, 4, 2).reshape(B, S, 4 * d)

    def step(carry, gx_t):
        h, c, n = carry
        h, c, n = slstm_cell_step(gx_t, params["r"], h, c, n, n_heads)
        return (h, c, n), h

    h0 = jnp.zeros((B, n_heads, dh), jnp.float32)
    carry, hs = jax.lax.scan(step, (h0, h0, h0), gx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(params["out_norm"], y)
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    if return_state:
        return out, carry
    return out


def slstm_decode(params, x, state, n_heads: int):
    B, _, d = x.shape
    dh = d // n_heads
    h, c, n = state
    gx = (x[:, 0] @ params["wx"]) + params["bias"]
    gx = gx.reshape(B, 4, n_heads, dh).transpose(0, 2, 3, 1).reshape(B, 4 * d)
    h, c, n = slstm_cell_step(gx, params["r"], h, c, n, n_heads)
    y = h.reshape(B, d).astype(x.dtype)
    y = rms_norm(params["out_norm"], y)
    out = (y @ params["wo"])[:, None, :]
    return out, (h, c, n)
