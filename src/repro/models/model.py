"""Unified decoder-LM model zoo: config → init / loss / prefill / decode.

Families
--------
dense   llama-style GQA transformer (granite-20b, deepseek-67b, yi-9b,
        llama3.2-3b; also the backbone of qwen2-vl and musicgen)
moe     dense attention + MoE FFN (phi3.5-moe)
mla_moe DeepSeek-V2: MLA attention + shared+routed MoE, first layer dense
hybrid  Zamba2: Mamba2 backbone + weight-shared attention block every k layers
xlstm   mLSTM blocks with sLSTM blocks at configured positions
vlm     dense backbone + patch-embedding scatter (frontend stub) + M-RoPE
audio   dense backbone over K EnCodec codebooks (summed embeds, K heads)

Uniform stacks are ``lax.scan``-ed over stacked layer params (compile-time
O(1) in depth) with configurable remat; heterogeneous stacks (hybrid, xlstm)
are Python loops with per-layer remat.  Caches are ``Param``-boxed so the
dry-run can derive shapes *and* shardings without allocating.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mla as MLA
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .common import KeyGen, Param, make_param, unbox


@dataclasses.dataclass
class ModelConfig:
    arch: str
    family: str                    # dense|moe|mla_moe|hybrid|xlstm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_layer_start: int = 0       # layers < start use the dense FFN
    # MLA
    q_lora: int = 0
    kv_lora: int = 0
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssd_decay_dtype: Any = jnp.float32   # bf16 = memory-term hillclimb lever
    attn_every: int = 0            # zamba2: shared attn block cadence
    # xLSTM
    slstm_every: int = 0           # 0 = no sLSTM layers; else layers i%k==1
    mlstm_chunk: int = 128
    # VLM
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    n_patches: int = 0
    # audio
    codebooks: int = 0
    # compute knobs (hillclimb levers)
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"     # full | dots | none
    q_chunk: int = 2048
    kv_chunk: int = 2048
    unroll_attention: bool = False
    dtype: Any = jnp.bfloat16
    seq_shard_activations: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // self.n_heads
        if self.d_ff_expert == 0 and self.n_experts:
            self.d_ff_expert = self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "xlstm"

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("hybrid", "xlstm")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS 6·N·D)."""
        shapes = jax.eval_shape(lambda: unbox(Model(self).init(jax.random.PRNGKey(0))))
        return sum(int(math.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (= N_active for MoE rooflines)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = self.n_layers - self.moe_layer_start
        inactive = per_expert * (self.n_experts - self.top_k) * n_moe_layers
        return total - inactive


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _stack_init(init_one, keys: KeyGen, n: int):
    """vmap an init over layer keys; prepend the 'layers' logical axis."""
    ks = jnp.stack([keys() for _ in range(n)])
    stacked = jax.vmap(init_one)(ks)
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, ("layers",) + p.axes),
        stacked, is_leaf=lambda x: isinstance(x, Param))


# =================================================================== Model ====
class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- init ----
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        keys = KeyGen(rng)
        p: Dict[str, Any] = {}
        if cfg.family == "audio":
            p["embed"] = make_param(keys(), (cfg.codebooks, cfg.vocab, cfg.d_model),
                                    (None, "vocab", "embed"), scale=0.02)
            p["heads"] = make_param(keys(), (cfg.codebooks, cfg.d_model, cfg.vocab),
                                    (None, "embed", "vocab"), scale=cfg.d_model ** -0.5)
        else:
            p["embed"] = make_param(keys(), (cfg.vocab, cfg.d_model),
                                    ("vocab", "embed"), scale=0.02)
            p["lm_head"] = make_param(keys(), (cfg.d_model, cfg.vocab),
                                      ("embed", "vocab"), scale=cfg.d_model ** -0.5)
        p["final_norm"] = L.rms_norm_init(keys(), cfg.d_model)

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            p["layers"] = self._maybe_stack(self._dense_layer_init, keys, cfg.n_layers)
        elif fam == "moe":
            p["layers"] = self._maybe_stack(self._moe_layer_init, keys, cfg.n_layers)
        elif fam == "mla_moe":
            p["layer0"] = self._mla_dense_layer_init(keys())
            p["layers"] = self._maybe_stack(self._mla_moe_layer_init, keys,
                                            cfg.n_layers - 1)
        elif fam == "hybrid":
            p["shared_attn"] = self._shared_attn_init(keys)
            p["layers"] = {f"l{i}": self._mamba_layer_init(keys())
                           for i in range(cfg.n_layers)}
            n_shared = len(self._shared_sites())
            p["shared_proj"] = {
                f"s{i}": make_param(keys(), (2 * cfg.d_model, cfg.d_model),
                                    ("embed", "embed2"), scale=(2 * cfg.d_model) ** -0.5)
                for i in range(n_shared)}
        elif fam == "xlstm":
            p["layers"] = {}
            for i in range(cfg.n_layers):
                if self._is_slstm(i):
                    p["layers"][f"l{i}"] = {"norm": L.rms_norm_init(keys(), cfg.d_model),
                                            "slstm": XL.slstm_init(keys, cfg.d_model,
                                                                   cfg.n_heads)}
                else:
                    p["layers"][f"l{i}"] = {"norm": L.rms_norm_init(keys(), cfg.d_model),
                                            "mlstm": XL.mlstm_init(keys, cfg.d_model,
                                                                   cfg.n_heads,
                                                                   cfg.ssm_expand)}
        else:
            raise ValueError(fam)
        return p

    def _maybe_stack(self, init_one, keys: KeyGen, n: int):
        if self.cfg.scan_layers:
            return _stack_init(init_one, keys, n)
        return {f"l{i}": init_one(keys()) for i in range(n)}

    def _dense_layer_init(self, key):
        cfg = self.cfg
        keys = KeyGen(key)
        return {
            "ln1": L.rms_norm_init(keys(), cfg.d_model),
            "attn": L.gqa_init(keys, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim),
            "ln2": L.rms_norm_init(keys(), cfg.d_model),
            "mlp": L.mlp_init(keys, cfg.d_model, cfg.d_ff),
        }

    def _moe_layer_init(self, key):
        cfg = self.cfg
        keys = KeyGen(key)
        return {
            "ln1": L.rms_norm_init(keys(), cfg.d_model),
            "attn": L.gqa_init(keys, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim),
            "ln2": L.rms_norm_init(keys(), cfg.d_model),
            "moe": MOE.moe_init(keys, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                                cfg.n_shared_experts),
        }

    def _mla_dense_layer_init(self, key):
        cfg = self.cfg
        keys = KeyGen(key)
        return {
            "ln1": L.rms_norm_init(keys(), cfg.d_model),
            "attn": MLA.mla_init(keys, cfg.d_model, cfg.n_heads, cfg.q_lora,
                                 cfg.kv_lora, cfg.nope_head_dim, cfg.rope_head_dim,
                                 cfg.v_head_dim),
            "ln2": L.rms_norm_init(keys(), cfg.d_model),
            "mlp": L.mlp_init(keys, cfg.d_model, cfg.d_ff_expert * 8),
        }

    def _mla_moe_layer_init(self, key):
        cfg = self.cfg
        keys = KeyGen(key)
        return {
            "ln1": L.rms_norm_init(keys(), cfg.d_model),
            "attn": MLA.mla_init(keys, cfg.d_model, cfg.n_heads, cfg.q_lora,
                                 cfg.kv_lora, cfg.nope_head_dim, cfg.rope_head_dim,
                                 cfg.v_head_dim),
            "ln2": L.rms_norm_init(keys(), cfg.d_model),
            "moe": MOE.moe_init(keys, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                                cfg.n_shared_experts),
        }

    def _mamba_layer_init(self, key):
        cfg = self.cfg
        keys = KeyGen(key)
        return {
            "norm": L.rms_norm_init(keys(), cfg.d_model),
            "mamba": SSM.mamba2_init(keys, cfg.d_model, cfg.ssm_expand * cfg.d_model,
                                     cfg.ssm_state, cfg.ssm_headdim),
        }

    def _shared_attn_init(self, keys: KeyGen):
        cfg = self.cfg
        return {
            "ln1": L.rms_norm_init(keys(), cfg.d_model),
            "attn": L.gqa_init(keys, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim),
            "ln2": L.rms_norm_init(keys(), cfg.d_model),
            "mlp": L.mlp_init(keys, cfg.d_model, cfg.d_ff),
        }

    def _shared_sites(self):
        cfg = self.cfg
        if not cfg.attn_every:
            return []
        return [i for i in range(cfg.n_layers) if i % cfg.attn_every == 0]

    def _is_slstm(self, i: int) -> bool:
        return bool(self.cfg.slstm_every) and i % self.cfg.slstm_every == 1

    # ------------------------------------------------------------- embedding ----
    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            # tokens [B,K,S] → summed codebook embeddings
            toks = batch["tokens"]
            x = jnp.zeros((toks.shape[0], toks.shape[2], cfg.d_model), cfg.dtype)
            for kb in range(cfg.codebooks):
                x = x + jnp.take(params["embed"][kb], toks[:, kb], axis=0)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            # modality stub: precomputed patch embeddings scattered over the
            # token sequence at patch_positions
            bidx = jnp.arange(x.shape[0])[:, None]
            x = x.at[bidx, batch["patch_positions"]].set(
                batch["patch_embeds"].astype(x.dtype))
        return L.lsc(x.astype(cfg.dtype), "batch", "seq", None)

    def _rope(self, batch, S, pos_offset=0):
        cfg = self.cfg
        if cfg.family == "vlm":
            if "positions3" in batch:
                pos3 = batch["positions3"]
            else:
                pos = pos_offset + jnp.arange(S)
                pos3 = jnp.broadcast_to(pos[None, :, None], (1, S, 3))
            return L.mrope_angles(pos3, cfg.head_dim, cfg.mrope_sections,
                                  cfg.rope_theta)
        pos = pos_offset + jnp.arange(S)
        return L.rope_angles(pos, cfg.head_dim, cfg.rope_theta)

    def _unembed(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(params["final_norm"], x)
        if cfg.family == "audio":
            logits = jnp.einsum("bsd,kdv->bskv", x, params["heads"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits.astype(jnp.float32)

    # ------------------------------------------------------------ forward ----
    def forward(self, params, batch):
        """Full-sequence forward → logits (params must be unboxed)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        S = x.shape[1]
        fam = cfg.family

        if fam in ("dense", "vlm", "audio", "moe", "mla_moe"):
            cos, sin = (self._rope(batch, S) if fam != "mla_moe" else (None, None))
            aux_total = jnp.zeros((), jnp.float32)

            if fam == "mla_moe":
                positions = jnp.arange(S)

                def block(x, lp):
                    h = MLA.mla_forward(lp["attn"], L.rms_norm(lp["ln1"], x), positions,
                                        cfg.nope_head_dim, cfg.rope_head_dim,
                                        cfg.rope_theta, cfg.q_chunk, cfg.kv_chunk,
                                        unroll=cfg.unroll_attention)
                    x = x + h
                    m, aux = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                             cfg.top_k, cfg.capacity_factor)
                    x = x + m
                    x = L.lsc(x, "batch", "act_seq", None)
                    return x, aux

                def block0(x, lp):
                    h = MLA.mla_forward(lp["attn"], L.rms_norm(lp["ln1"], x), positions,
                                        cfg.nope_head_dim, cfg.rope_head_dim,
                                        cfg.rope_theta, cfg.q_chunk, cfg.kv_chunk,
                                        unroll=cfg.unroll_attention)
                    x = x + h
                    x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
                    return x

                x = _remat(block0, cfg)(x, params["layer0"])
                x, auxs = self._run_stack(block, x, params["layers"], cfg.n_layers - 1)
                aux_total = auxs
            elif fam == "moe":
                def block(x, lp):
                    h = L.gqa_forward(lp["attn"], L.rms_norm(lp["ln1"], x), cos, sin,
                                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                      unroll=cfg.unroll_attention)
                    x = x + h
                    m, aux = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                             cfg.top_k, cfg.capacity_factor)
                    x = x + m
                    x = L.lsc(x, "batch", "act_seq", None)
                    return x, aux

                x, aux_total = self._run_stack(block, x, params["layers"], cfg.n_layers)
            else:
                def block(x, lp):
                    h = L.gqa_forward(lp["attn"], L.rms_norm(lp["ln1"], x), cos, sin,
                                      q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                      unroll=cfg.unroll_attention)
                    x = x + h
                    x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
                    x = L.lsc(x, "batch", "act_seq", None)
                    return x, jnp.zeros((), jnp.float32)

                x, aux_total = self._run_stack(block, x, params["layers"], cfg.n_layers)
            return self._unembed(params, x), aux_total

        if fam == "hybrid":
            x0 = x
            cos, sin = L.rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
            sites = self._shared_sites()
            site_no = 0
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if i in sites:
                    x = self._shared_attn_apply(params, x, x0, site_no, cos, sin)
                    site_no += 1

                def mblock(x, lp=lp):
                    return x + SSM.mamba2_forward(lp["mamba"],
                                                  L.rms_norm(lp["norm"], x),
                                                  cfg.ssm_chunk,
                                                  decay_dtype=cfg.ssd_decay_dtype)

                x = _remat(mblock, cfg)(x)
            return self._unembed(params, x), jnp.zeros((), jnp.float32)

        if fam == "xlstm":
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if self._is_slstm(i):
                    def sblock(x, lp=lp):
                        return x + XL.slstm_forward(lp["slstm"],
                                                    L.rms_norm(lp["norm"], x),
                                                    cfg.n_heads)
                    x = _remat(sblock, cfg)(x)
                else:
                    def mblock(x, lp=lp):
                        return x + XL.mlstm_forward(lp["mlstm"],
                                                    L.rms_norm(lp["norm"], x),
                                                    cfg.n_heads, cfg.mlstm_chunk)
                    x = _remat(mblock, cfg)(x)
            return self._unembed(params, x), jnp.zeros((), jnp.float32)

        raise ValueError(fam)

    def _shared_attn_apply(self, params, x, x0, site_no, cos, sin):
        """Zamba2 shared block: concat(x, embeddings) → proj → shared attn+mlp."""
        cfg = self.cfg
        sp = params["shared_attn"]
        proj = params["shared_proj"][f"s{site_no}"]

        def block(x):
            h = jnp.concatenate([x, x0], axis=-1) @ proj
            h = h + L.gqa_forward(sp["attn"], L.rms_norm(sp["ln1"], h), cos, sin,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                  unroll=cfg.unroll_attention)
            h = h + L.mlp_forward(sp["mlp"], L.rms_norm(sp["ln2"], h))
            return x + h

        return _remat(block, cfg)(x)

    def _run_stack(self, block, x, layer_params, n_layers):
        cfg = self.cfg
        if cfg.scan_layers:
            body = _remat(block, cfg)

            def scan_body(x, lp):
                return body(x, lp)

            x, auxs = jax.lax.scan(scan_body, x, layer_params)
            return x, jnp.sum(auxs)
        aux_total = jnp.zeros((), jnp.float32)
        body = _remat(block, cfg)
        for i in range(n_layers):
            x, aux = body(x, layer_params[f"l{i}"])
            aux_total = aux_total + aux
        return x, aux_total

    # ---------------------------------------------------------------- loss ----
    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        targets = batch["targets"]
        if self.cfg.family == "audio":
            # logits [B,S,K,V], targets [B,K,S]
            targets = targets.transpose(0, 2, 1)
        mask = (targets >= 0).astype(jnp.float32)
        tgt = jnp.maximum(targets, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + 0.01 * aux, {"nll": loss, "aux": aux}

    # -------------------------------------------------------------- prefill ----
    def init_cache(self, batch_size: int, max_len: int):
        """Boxed zero cache (axes drive the dry-run shardings)."""
        cfg = self.cfg
        fam = cfg.family
        dt = cfg.dtype
        if fam in ("dense", "vlm", "audio", "moe"):
            kv = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
            axes = ("layers", "batch", "seq_kv", "kv_heads", None)
            return {"k": Param(jnp.zeros(kv, dt), axes),
                    "v": Param(jnp.zeros(kv, dt), axes),
                    "pos": Param(jnp.zeros((), jnp.int32), ())}
        if fam == "mla_moe":
            return {
                "ckv": Param(jnp.zeros((cfg.n_layers, batch_size, max_len, cfg.kv_lora), dt),
                             ("layers", "batch", "seq_kv", None)),
                "kr": Param(jnp.zeros((cfg.n_layers, batch_size, max_len, cfg.rope_head_dim), dt),
                            ("layers", "batch", "seq_kv", None)),
                "pos": Param(jnp.zeros((), jnp.int32), ()),
            }
        if fam == "hybrid":
            di = cfg.ssm_expand * cfg.d_model
            H = di // cfg.ssm_headdim
            n_sites = len(self._shared_sites())
            return {
                "ssm": Param(jnp.zeros((cfg.n_layers, batch_size, H, cfg.ssm_state,
                                        cfg.ssm_headdim), jnp.float32),
                             ("layers", "batch", None, None, None)),
                "conv": Param(jnp.zeros((cfg.n_layers, batch_size, 3, di), dt),
                              ("layers", "batch", None, "ffn")),
                "k": Param(jnp.zeros((n_sites, batch_size, max_len, cfg.n_kv_heads,
                                      cfg.head_dim), dt),
                           (None, "batch", "seq_kv", "kv_heads", None)),
                "v": Param(jnp.zeros((n_sites, batch_size, max_len, cfg.n_kv_heads,
                                      cfg.head_dim), dt),
                           (None, "batch", "seq_kv", "kv_heads", None)),
                "pos": Param(jnp.zeros((), jnp.int32), ()),
            }
        if fam == "xlstm":
            di = cfg.ssm_expand * cfg.d_model
            Dh = di // cfg.n_heads
            dh = cfg.d_model // cfg.n_heads
            n_s = sum(1 for i in range(cfg.n_layers) if self._is_slstm(i))
            n_m = cfg.n_layers - n_s
            return {
                "C": Param(jnp.zeros((n_m, batch_size, cfg.n_heads, Dh, Dh), jnp.float32),
                           ("layers", "batch", None, None, None)),
                "n": Param(jnp.zeros((n_m, batch_size, cfg.n_heads, Dh), jnp.float32),
                           ("layers", "batch", None, None)),
                "s_h": Param(jnp.zeros((max(n_s, 1), 3, batch_size, cfg.n_heads, dh),
                                       jnp.float32),
                             ("layers", None, "batch", None, None)),
                "pos": Param(jnp.zeros((), jnp.int32), ()),
            }
        raise ValueError(fam)

    # -------------------------------------------------------------- decode ----
    def decode(self, params, cache, batch):
        """One decode step: batch['tokens'] [B,1] (audio: [B,K,1]).
        Returns (logits, new_cache).  params/cache unboxed."""
        cfg = self.cfg
        fam = cfg.family
        pos = cache["pos"]
        x = self._embed_decode(params, batch)
        B = x.shape[0]

        if fam in ("dense", "vlm", "audio", "moe"):
            posb = jnp.full((B, 1), pos, jnp.int32)
            if fam == "vlm":
                pos3 = jnp.broadcast_to(posb[..., None], (B, 1, 3))
                cos, sin = L.mrope_angles(pos3, cfg.head_dim, cfg.mrope_sections,
                                          cfg.rope_theta)
            else:
                cos, sin = L.rope_angles(posb, cfg.head_dim, cfg.rope_theta)

            if cfg.scan_layers:
                def body(x, lp_and_cache):
                    lp, ck, cv = lp_and_cache
                    h, ck, cv = self._decode_block(lp, x, ck, cv, pos, cos, sin)
                    return h, (ck, cv)

                x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                                     cache["v"]))
                cache = {**cache, "k": ks, "v": vs, "pos": pos + 1}
            else:
                ks, vs = [], []
                for i in range(cfg.n_layers):
                    lp = params["layers"][f"l{i}"]
                    x, ck, cv = self._decode_block(lp, x, cache["k"][i], cache["v"][i],
                                                   pos, cos, sin)
                    ks.append(ck)
                    vs.append(cv)
                cache = {**cache, "k": jnp.stack(ks), "v": jnp.stack(vs), "pos": pos + 1}
            return self._unembed(params, x)[:, -1], cache

        if fam == "mla_moe":
            def mla_block(x, lp, ckv, kr, dense_mlp):
                h, ckv, kr = MLA.mla_decode(lp["attn"], L.rms_norm(lp["ln1"], x),
                                            ckv, kr, pos, cfg.nope_head_dim,
                                            cfg.rope_head_dim, cfg.rope_theta)
                x = x + h
                if dense_mlp:
                    x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
                else:
                    m, _ = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                           cfg.top_k, cfg.capacity_factor)
                    x = x + m
                return x, ckv, kr

            x, ckv0, kr0 = mla_block(x, params["layer0"], cache["ckv"][0],
                                     cache["kr"][0], True)

            def body(x, lp_and_cache):
                lp, ckv, kr = lp_and_cache
                x, ckv, kr = mla_block(x, lp, ckv, kr, False)
                return x, (ckv, kr)

            if cfg.scan_layers:
                x, (ckvs, krs) = jax.lax.scan(
                    body, x, (params["layers"], cache["ckv"][1:], cache["kr"][1:]))
            else:
                outs = []
                for i in range(cfg.n_layers - 1):
                    x, out = body(x, (params["layers"][f"l{i}"],
                                      cache["ckv"][1 + i], cache["kr"][1 + i]))
                    outs.append(out)
                ckvs = jnp.stack([o[0] for o in outs])
                krs = jnp.stack([o[1] for o in outs])
            cache = {**cache,
                     "ckv": jnp.concatenate([ckv0[None], ckvs]),
                     "kr": jnp.concatenate([kr0[None], krs]),
                     "pos": pos + 1}
            return self._unembed(params, x)[:, -1], cache

        if fam == "hybrid":
            x0 = x
            posb = jnp.full((B, 1), pos, jnp.int32)
            cos, sin = L.rope_angles(posb, cfg.head_dim, cfg.rope_theta)
            sites = self._shared_sites()
            site_no = 0
            ssm_states, conv_states = [], []
            ks, vs = list(cache["k"]), list(cache["v"])
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if i in sites:
                    sp = params["shared_attn"]
                    proj = params["shared_proj"][f"s{site_no}"]
                    h = jnp.concatenate([x, x0], axis=-1) @ proj
                    a, ks[site_no], vs[site_no] = L.gqa_decode(
                        sp["attn"], L.rms_norm(sp["ln1"], h), ks[site_no], vs[site_no],
                        pos, cos, sin)
                    h = h + a
                    h = h + L.mlp_forward(sp["mlp"], L.rms_norm(sp["ln2"], h))
                    x = x + h
                    site_no += 1
                out, s, cc = SSM.mamba2_decode(lp["mamba"], L.rms_norm(lp["norm"], x),
                                               cache["ssm"][i], cache["conv"][i])
                x = x + out
                ssm_states.append(s)
                conv_states.append(cc)
            cache = {"ssm": jnp.stack(ssm_states), "conv": jnp.stack(conv_states),
                     "k": jnp.stack(ks), "v": jnp.stack(vs), "pos": pos + 1}
            return self._unembed(params, x)[:, -1], cache

        if fam == "xlstm":
            Cs, ns, shs = [], [], []
            mi = si = 0
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if self._is_slstm(i):
                    st = tuple(cache["s_h"][si])
                    out, st = XL.slstm_decode(lp["slstm"], L.rms_norm(lp["norm"], x),
                                              st, cfg.n_heads)
                    shs.append(jnp.stack(st))
                    si += 1
                else:
                    out, (C, n) = XL.mlstm_decode(lp["mlstm"],
                                                  L.rms_norm(lp["norm"], x),
                                                  (cache["C"][mi], cache["n"][mi]),
                                                  cfg.n_heads)
                    Cs.append(C)
                    ns.append(n)
                    mi += 1
                x = x + out
            cache = {"C": jnp.stack(Cs), "n": jnp.stack(ns),
                     "s_h": jnp.stack(shs) if shs else cache["s_h"],
                     "pos": pos + 1}
            return self._unembed(params, x)[:, -1], cache

        raise ValueError(fam)

    def _decode_block(self, lp, x, ck, cv, pos, cos, sin):
        cfg = self.cfg
        h, ck, cv = L.gqa_decode(lp["attn"], L.rms_norm(lp["ln1"], x), ck, cv, pos,
                                 cos, sin)
        x = x + h
        if "mlp" in lp:
            x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
        else:
            m, _ = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                   cfg.top_k, cfg.capacity_factor)
            x = x + m
        return x, ck, cv

    def _embed_decode(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            toks = batch["tokens"]  # [B,K,1]
            x = jnp.zeros((toks.shape[0], 1, cfg.d_model), cfg.dtype)
            for kb in range(cfg.codebooks):
                x = x + jnp.take(params["embed"][kb], toks[:, kb], axis=0)
            return x
        return jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.dtype)

    # ------------------------------------------------------------- prefill ----
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Forward over the prompt, returning (last_logits, populated cache).

        For the attention families the K/V computed during the forward pass are
        written into a fresh cache; SSM/xLSTM families return final states."""
        cfg = self.cfg
        fam = cfg.family
        S = batch["tokens"].shape[-1]
        B = batch["tokens"].shape[0]
        max_len = max_len or S
        cache = unbox(self.init_cache(B, max_len))

        if fam in ("dense", "vlm", "audio", "moe"):
            x = self._embed(params, batch)
            cos, sin = self._rope(batch, S)
            ks, vs = [], []

            def block(x, lp):
                h, (k, v) = L.gqa_forward(lp["attn"], L.rms_norm(lp["ln1"], x), cos,
                                          sin, q_chunk=cfg.q_chunk,
                                          kv_chunk=cfg.kv_chunk, return_kv=True,
                                          unroll=cfg.unroll_attention)
                x = x + h
                if "mlp" in lp:
                    x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
                else:
                    m, _ = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                           cfg.top_k, cfg.capacity_factor)
                    x = x + m
                x = L.lsc(x, "batch", "act_seq", None)
                return x, (k.astype(cfg.dtype), v.astype(cfg.dtype))

            if cfg.scan_layers:
                x, (ks, vs) = jax.lax.scan(_remat(block, cfg), x, params["layers"])
            else:
                kl, vl = [], []
                for i in range(cfg.n_layers):
                    x, (k, v) = _remat(block, cfg)(x, params["layers"][f"l{i}"])
                    kl.append(k)
                    vl.append(v)
                ks, vs = jnp.stack(kl), jnp.stack(vl)
            pad = max_len - S
            if pad:
                ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache.update({"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)})
            return self._unembed(params, x)[:, -1], cache

        if fam == "mla_moe":
            x = self._embed(params, batch)
            positions = jnp.arange(S)
            ckvs, krs = [], []

            def block(x, lp, dense_mlp):
                h, (ckv, kr) = MLA.mla_forward(
                    lp["attn"], L.rms_norm(lp["ln1"], x), positions,
                    cfg.nope_head_dim, cfg.rope_head_dim, cfg.rope_theta,
                    cfg.q_chunk, cfg.kv_chunk, return_cache=True,
                    unroll=cfg.unroll_attention)
                x = x + h
                if dense_mlp:
                    x = x + L.mlp_forward(lp["mlp"], L.rms_norm(lp["ln2"], x))
                else:
                    m, _ = MOE.moe_forward(lp["moe"], L.rms_norm(lp["ln2"], x),
                                           cfg.top_k, cfg.capacity_factor)
                    x = x + m
                return x, (ckv.astype(cfg.dtype), kr.astype(cfg.dtype))

            x, (ckv0, kr0) = _remat(partial(block, dense_mlp=True), cfg)(
                x, params["layer0"])

            def body(x, lp):
                return _remat(partial(block, dense_mlp=False), cfg)(x, lp)

            if cfg.scan_layers:
                x, (ckvs, krs) = jax.lax.scan(body, x, params["layers"])
            else:
                outs = []
                for i in range(cfg.n_layers - 1):
                    x, out = body(x, params["layers"][f"l{i}"])
                    outs.append(out)
                ckvs = jnp.stack([o[0] for o in outs])
                krs = jnp.stack([o[1] for o in outs])
            ckvs = jnp.concatenate([ckv0[None], ckvs])
            krs = jnp.concatenate([kr0[None], krs])
            pad = max_len - S
            if pad:
                ckvs = jnp.pad(ckvs, ((0, 0), (0, 0), (0, pad), (0, 0)))
                krs = jnp.pad(krs, ((0, 0), (0, 0), (0, pad), (0, 0)))
            cache.update({"ckv": ckvs, "kr": krs, "pos": jnp.asarray(S, jnp.int32)})
            return self._unembed(params, x)[:, -1], cache

        if fam == "hybrid":
            x = self._embed(params, batch)
            x0 = x
            cos, sin = L.rope_angles(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
            sites = self._shared_sites()
            site_no = 0
            ssm_states, conv_states, ks, vs = [], [], [], []
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if i in sites:
                    sp = params["shared_attn"]
                    proj = params["shared_proj"][f"s{site_no}"]
                    h = jnp.concatenate([x, x0], axis=-1) @ proj
                    a, (k, v) = L.gqa_forward(sp["attn"], L.rms_norm(sp["ln1"], h),
                                              cos, sin, q_chunk=cfg.q_chunk,
                                              kv_chunk=cfg.kv_chunk, return_kv=True,
                                              unroll=cfg.unroll_attention)
                    h = h + a
                    h = h + L.mlp_forward(sp["mlp"], L.rms_norm(sp["ln2"], h))
                    x = x + h
                    pad = max_len - S
                    k, v = k.astype(cfg.dtype), v.astype(cfg.dtype)
                    if pad:
                        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    ks.append(k)
                    vs.append(v)
                    site_no += 1
                out, (s, cc) = SSM.mamba2_forward(lp["mamba"],
                                                  L.rms_norm(lp["norm"], x),
                                                  cfg.ssm_chunk, return_state=True,
                                                  decay_dtype=cfg.ssd_decay_dtype)
                x = x + out
                ssm_states.append(s)
                conv_states.append(cc.astype(cfg.dtype))
            cache.update({"ssm": jnp.stack(ssm_states), "conv": jnp.stack(conv_states),
                          "k": jnp.stack(ks), "v": jnp.stack(vs),
                          "pos": jnp.asarray(S, jnp.int32)})
            return self._unembed(params, x)[:, -1], cache

        if fam == "xlstm":
            x = self._embed(params, batch)
            Cs, ns, shs = [], [], []
            for i in range(cfg.n_layers):
                lp = params["layers"][f"l{i}"]
                if self._is_slstm(i):
                    out, st = XL.slstm_forward(lp["slstm"], L.rms_norm(lp["norm"], x),
                                               cfg.n_heads, return_state=True)
                    shs.append(jnp.stack(st))
                else:
                    out, (C, n) = XL.mlstm_forward(lp["mlstm"],
                                                   L.rms_norm(lp["norm"], x),
                                                   cfg.n_heads, cfg.mlstm_chunk,
                                                   return_state=True)
                    Cs.append(C)
                    ns.append(n)
                x = x + out
            cache.update({"C": jnp.stack(Cs), "n": jnp.stack(ns),
                          "pos": jnp.asarray(S, jnp.int32)})
            if shs:
                cache["s_h"] = jnp.stack(shs)
            return self._unembed(params, x)[:, -1], cache

        raise ValueError(fam)
