"""Core transformer layers, pure JAX: RMSNorm, RoPE / M-RoPE, GQA attention
(with a chunked online-softmax "flash" path that never materializes the S×S
matrix — the XLA production path; the Pallas TPU kernel in
``repro.kernels.flash_attention`` is the hardware hot-spot version), and the
SwiGLU MLP.  All parameters are ``Param``-boxed with logical sharding axes.
"""
from __future__ import annotations

import math
from contextvars import ContextVar

import jax
import jax.numpy as jnp

from .common import KeyGen, make_param

# -- logical activation sharding ----------------------------------------------------
# The distributed layer installs a resolver(logical_axes_tuple) -> PartitionSpec;
# model code annotates activations with logical axes and stays mesh-agnostic.
_ACT_RESOLVER: ContextVar = ContextVar("act_resolver", default=None)


def set_activation_resolver(resolver):
    return _ACT_RESOLVER.set(resolver)


def reset_activation_resolver(token):
    _ACT_RESOLVER.reset(token)


def lsc(x, *axes):
    """logical sharding constraint (no-op outside a mesh context)."""
    resolver = _ACT_RESOLVER.get()
    if resolver is None:
        return x
    sharding = resolver(axes, x.shape)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


# -- norms ---------------------------------------------------------------------------
def rms_norm_init(key, d, name="norm"):
    return {"w": make_param(key, (d,), ("embed",), init="ones")}


def rms_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["w"].astype(jnp.float32)).astype(x.dtype)


# -- RoPE ----------------------------------------------------------------------------
def rope_angles(positions, head_dim: int, theta: float = 10000.0):
    """positions [...]: int -> cos/sin [..., head_dim/2] in fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B,S,H,D]; cos/sin [B,S,D/2] or [S,D/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mrope_angles(positions3, head_dim: int, sections, theta: float = 10000.0):
    """Qwen2-VL M-RoPE: positions3 [B,S,3] (t,h,w); ``sections`` split the
    rotary half-dim across the three position streams."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    coss, sins = [], []
    start = 0
    for i, sec in enumerate(sections):
        pos = positions3[..., i].astype(jnp.float32)  # [B,S]
        ang = pos[..., None] * freqs[start:start + sec]
        coss.append(jnp.cos(ang))
        sins.append(jnp.sin(ang))
        start += sec
    return jnp.concatenate(coss, -1), jnp.concatenate(sins, -1)  # [B,S,half]


# -- attention ------------------------------------------------------------------------
def attention_naive(q, k, v, causal=True, kv_len=None, pos_offset=0):
    """Reference O(S²)-memory attention (oracle for tests; never the prod path).
    q [B,Sq,Hq,D], k/v [B,Skv,Hkv,D] with Hq = G*Hkv."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    q_pos = pos_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if kv_len is not None:
        mask &= kv_pos[None, :] < kv_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", p, v)
    return out.reshape(B, Sq, Hq, D)


def attention_chunked(q, k, v, causal=True, kv_len=None, pos_offset=0,
                      q_chunk=2048, kv_chunk=2048, unroll=False):
    """Online-softmax flash attention in pure JAX: double scan over q/kv chunks.
    Peak intermediate is [B,Hkv,G,qc,kc] — no S×S materialization.

    ``unroll=True`` emits the chunk loops as straight-line HLO (and *skips*
    fully-masked causal blocks — the triangular schedule, ~2× fewer FLOPs).
    Used by the dry-run analysis probes (XLA cost analysis does not scale
    ``while`` bodies by trip count) and available as a production option."""
    if unroll:
        return _attention_unrolled(q, k, v, causal, kv_len, pos_offset,
                                   q_chunk, kv_chunk)
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # value head dim may differ (MLA)
    G = Hq // Hkv
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    # pad to multiples
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0))) if nq * qc != Sq else q
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0))) if nk * kc != Skv else k
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0))) if nk * kc != Skv else v
    qg = q.reshape(B, nq, qc, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,Hkv,G,qc,D]
    kg = k.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 3, 2, 4)        # [nk,B,Hkv,kc,D]
    vg = v.reshape(B, nk, kc, Hkv, Dv).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / math.sqrt(D)
    neg = jnp.float32(-1e30)

    def q_block(carry, inp):
        iq, qb = inp  # qb [B,Hkv,G,qc,D]
        q_pos = pos_offset + iq * qc + jnp.arange(qc)

        def kv_block(acc, kin):
            ik, kb, vb = kin
            m_prev, l_prev, o_prev = acc
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb).astype(jnp.float32) * scale
            kv_pos = ik * kc + jnp.arange(kc)
            msk = jnp.ones((qc, kc), bool)
            if causal:
                msk &= q_pos[:, None] >= kv_pos[None, :]
            if kv_len is not None:
                msk &= (kv_pos < kv_len)[None, :]
            else:
                msk &= (kv_pos < Skv)[None, :]
            s = jnp.where(msk[None, None, None], s, neg)
            m_new = jnp.maximum(m_prev, s.max(-1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_prev * alpha + p.sum(-1)
            o_new = o_prev * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, G, qc), neg, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, qc, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0), (jnp.arange(nk), kg, vg))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return carry, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qg))
    # outs [nq,B,Hkv,G,qc,Dv] -> [B,S,Hq,Dv]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qc, Hq, Dv)
    return out[:, :Sq]


def _attention_unrolled(q, k, v, causal, kv_len, pos_offset, q_chunk, kv_chunk):
    """Straight-line flash attention with causal block skipping."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0))) if nq * qc != Sq else q
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0))) if nk * kc != Skv else k
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0))) if nk * kc != Skv else v
    scale = 1.0 / math.sqrt(D)
    neg = jnp.float32(-1e30)
    outs = []
    for iq in range(nq):
        qb = q[:, iq * qc:(iq + 1) * qc].reshape(B, qc, Hkv, G, D)
        qb = qb.transpose(0, 2, 3, 1, 4)  # [B,Hkv,G,qc,D]
        q_pos = pos_offset + iq * qc + jnp.arange(qc)
        q_end = pos_offset + (iq + 1) * qc - 1
        m = jnp.full((B, Hkv, G, qc), neg, jnp.float32)
        l = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        o = jnp.zeros((B, Hkv, G, qc, Dv), jnp.float32)
        for ik in range(nk):
            if causal and ik * kc > q_end:
                continue  # fully-masked block: triangular skip
            kb = k[:, ik * kc:(ik + 1) * kc].transpose(0, 2, 1, 3)  # [B,Hkv,kc,D]
            vb = v[:, ik * kc:(ik + 1) * kc].transpose(0, 2, 1, 3)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb).astype(jnp.float32) * scale
            kv_pos = ik * kc + jnp.arange(kc)
            msk = jnp.ones((qc, kc), bool)
            if causal:
                msk &= q_pos[:, None] >= kv_pos[None, :]
            msk &= (kv_pos < (Skv if kv_len is None else kv_len))[None, :]
            s = jnp.where(msk[None, None, None], s, neg)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(-1)
            o = o * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            m = m_new
        out = (o / jnp.maximum(l[..., None], 1e-30)).astype(v.dtype)
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, qc, Hq, Dv))
    return jnp.concatenate(outs, axis=1)[:, :Sq]


def attention_decode(q, k_cache, v_cache, pos):
    """Single-token decode vs a (padded) cache.  q [B,1,Hq,D],
    caches [B,T,Hkv,D], ``pos`` = number of valid cache entries (int or [B])."""
    B, _, Hq, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache).astype(jnp.float32)
    s = s / math.sqrt(D)
    kv_pos = jnp.arange(T)
    valid = kv_pos[None, :] < (pos if jnp.ndim(pos) else pos + jnp.zeros((B,), jnp.int32))[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache)
    return out.reshape(B, 1, Hq, D)


# -- GQA attention block ----------------------------------------------------------------
def gqa_init(keys: KeyGen, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    return {
        "wq": make_param(keys(), (d_model, n_heads, head_dim), ("embed", "heads", "head"),
                         scale=d_model ** -0.5),
        "wk": make_param(keys(), (d_model, n_kv, head_dim), ("embed", "kv_heads", "head"),
                         scale=d_model ** -0.5),
        "wv": make_param(keys(), (d_model, n_kv, head_dim), ("embed", "kv_heads", "head"),
                         scale=d_model ** -0.5),
        "wo": make_param(keys(), (n_heads, head_dim, d_model), ("heads", "head", "embed"),
                         scale=(n_heads * head_dim) ** -0.5),
    }


def gqa_qkv(params, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    return q, k, v


def gqa_out(params, attn):
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"])


def gqa_forward(params, x, cos, sin, causal=True, q_chunk=2048, kv_chunk=2048,
                return_kv=False, unroll=False):
    q, k, v = gqa_qkv(params, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = lsc(q, "batch", "seq", "heads", None)
    k = lsc(k, "batch", "seq", "kv_heads", None)
    attn = attention_chunked(q, k, v, causal=causal, q_chunk=q_chunk,
                             kv_chunk=kv_chunk, unroll=unroll)
    out = gqa_out(params, attn)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(params, x, cache_k, cache_v, pos, cos, sin):
    """x [B,1,D]; writes K/V at ``pos`` and attends over the valid prefix."""
    q, k, v = gqa_qkv(params, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, 1)
    out = attention_decode(q, cache_k, cache_v, pos + 1)
    return gqa_out(params, out), cache_k, cache_v


# -- SwiGLU MLP -----------------------------------------------------------------------
def mlp_init(keys: KeyGen, d_model: int, d_ff: int):
    return {
        "wg": make_param(keys(), (d_model, d_ff), ("embed", "ffn"), scale=d_model ** -0.5),
        "wu": make_param(keys(), (d_model, d_ff), ("embed", "ffn"), scale=d_model ** -0.5),
        "wd": make_param(keys(), (d_ff, d_model), ("ffn", "embed"), scale=d_ff ** -0.5),
    }


def mlp_forward(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["wg"])
    u = jnp.einsum("bsd,df->bsf", x, params["wu"])
    h = jax.nn.silu(g) * u
    h = lsc(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, params["wd"])
