"""Mixture-of-Experts layer: sort-based capacity dispatch (Megablocks-style,
TPU-native adaptation).

Instead of the classic one-hot dispatch einsum (T×E×C×D FLOPs — ruinous at
160 experts), tokens are argsorted by expert id and gathered into dense
[E, C, D] groups, so expert matmul FLOPs are exactly
``tokens × top_k × capacity_factor × expert_FFN`` — matching MODEL_FLOPS for
MoE in the roofline.  Gathers/scatters are memory ops, not FLOPs.  Tokens
beyond an expert's capacity are dropped (contribute only via residual/shared
experts), standard Switch behaviour.

Sharding: experts over the 'model' mesh axis (expert parallelism), tokens over
'data' — the gather across them lowers to the EP all-to-all exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, make_param
from .layers import lsc, mlp_forward, mlp_init


def moe_init(keys: KeyGen, d_model: int, d_ff_expert: int, n_experts: int,
             n_shared: int = 0):
    p = {
        "router": make_param(keys(), (d_model, n_experts), ("embed", None),
                             scale=d_model ** -0.5),
        "wg": make_param(keys(), (n_experts, d_model, d_ff_expert),
                         ("experts", "embed", "ffn"), scale=d_model ** -0.5),
        "wu": make_param(keys(), (n_experts, d_model, d_ff_expert),
                         ("experts", "embed", "ffn"), scale=d_model ** -0.5),
        "wd": make_param(keys(), (n_experts, d_ff_expert, d_model),
                         ("experts", "ffn", "embed"), scale=d_ff_expert ** -0.5),
    }
    if n_shared > 0:
        p["shared"] = mlp_init(keys, d_model, d_ff_expert * n_shared)
    return p


def moe_forward(params, x, top_k: int, capacity_factor: float = 1.25,
                router_in_fp32: bool = True):
    """x [B,S,D] -> [B,S,D].  Returns (out, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E = params["router"].shape[-1]
    xf = x.reshape(T, D)

    rl = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32) \
        if router_in_fp32 else xf @ params["router"]
    probs = jax.nn.softmax(rl.astype(jnp.float32), axis=-1)  # [T,E]
    top_p, top_e = jax.lax.top_k(probs, top_k)               # [T,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * top_k)
    aux_loss = E * jnp.sum(me * ce)

    TK = T * top_k
    cap = int(max(1, -(-TK // E) * capacity_factor))
    flat_e = top_e.reshape(TK)
    flat_p = top_p.reshape(TK)

    # sort token-slots by expert; each expert owns a contiguous range
    sort_idx = jnp.argsort(flat_e)                    # [TK]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = offsets[:, None] + jnp.arange(cap)[None, :]          # [E,C]
    valid = jnp.arange(cap)[None, :] < counts[:, None]
    slot = jnp.minimum(slot, TK - 1)
    token_slot = jnp.take(sort_idx, slot, axis=0)               # [E,C] -> flat slots
    token_idx = token_slot // top_k                             # [E,C] -> tokens
    gate = jnp.take(flat_p, token_slot, axis=0) * valid         # [E,C] fp32

    expert_in = jnp.take(xf, token_idx.reshape(-1), axis=0).reshape(E, cap, D)
    expert_in = lsc(expert_in, "experts", None, None)
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["wg"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["wu"])
    h = jax.nn.silu(g) * u
    h = lsc(h, "experts", None, "ffn")
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wd"])
    out_e = out_e * gate[..., None].astype(out_e.dtype)

    out = jnp.zeros((T, D), out_e.dtype).at[token_idx.reshape(-1)].add(
        out_e.reshape(E * cap, D))
    out = lsc(out.reshape(B, S, D), "batch", "seq", None)

    if "shared" in params:
        out = out + mlp_forward(params["shared"], x)
    return out, aux_loss
