"""Mamba2 (SSD — state space duality) block, chunked matmul formulation.

TPU adaptation: the selective scan is computed chunk-wise so nearly all work
is MXU-shaped matmuls (the Mamba2 paper's own SSD algorithm); only the
inter-chunk state recurrence is a short ``lax.scan`` over S/Q steps.  The
recurrent single-step path (decode) uses the same discretization
``h_t = exp(a·dt_t)·h_{t-1} + dt_t·B_t⊗x_t``; chunked == recurrent is
property-tested.

Simplifications vs the reference CUDA impl (noted in DESIGN.md): the short
causal conv applies to the x-branch only (not B/C), single B/C group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import KeyGen, make_param
from .layers import lsc, rms_norm, rms_norm_init


def mamba2_init(keys: KeyGen, d_model: int, d_inner: int, n_state: int,
                headdim: int = 64, conv_width: int = 4):
    H = d_inner // headdim
    return {
        "wz": make_param(keys(), (d_model, d_inner), ("embed", "ffn"), scale=d_model ** -0.5),
        "wx": make_param(keys(), (d_model, d_inner), ("embed", "ffn"), scale=d_model ** -0.5),
        "conv_w": make_param(keys(), (conv_width, d_inner), (None, "ffn"), scale=0.5),
        "conv_b": make_param(keys(), (d_inner,), ("ffn",), init="zeros"),
        "wB": make_param(keys(), (d_model, n_state), ("embed", None), scale=d_model ** -0.5),
        "wC": make_param(keys(), (d_model, n_state), ("embed", None), scale=d_model ** -0.5),
        "wdt": make_param(keys(), (d_model, H), ("embed", None), scale=d_model ** -0.5),
        "dt_bias": make_param(keys(), (H,), (None,), init="zeros"),
        "a_log": make_param(keys(), (H,), (None,), init="zeros"),  # a = -exp(a_log)
        "d_skip": make_param(keys(), (H,), (None,), init="ones"),
        "out_norm": rms_norm_init(keys(), d_inner),
        "wo": make_param(keys(), (d_inner, d_model), ("ffn", "embed"), scale=d_inner ** -0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B,S,Di], w [W,Di]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _ssd_chunked(xh, B_, C_, dt, a, chunk: int, decay_dtype=jnp.float32):
    """xh [B,S,H,P], B_/C_ [B,S,N], dt [B,S,H] (>0), a [H] (<0).
    Returns y [B,S,H,P] and the final state [B,H,N,P].

    ``decay_dtype=bf16`` halves the bytes of the intra-chunk decay tensor
    chain ([B,nc,Q,Q,H] — the memory hot spot at training shapes); decay
    values live in [0,1] so relative error stays ~1e-2 (hillclimb lever)."""
    Bsz, S, H, P = xh.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    S0 = S
    if S % Q:
        # pad to a chunk multiple with *neutral* steps: dt=0 ⇒ decay=1 and
        # zero state contribution, so padded steps are exact no-ops
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    f32 = jnp.float32

    xc = xh.reshape(Bsz, nc, Q, H, P)
    Bc = B_.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = C_.reshape(Bsz, nc, Q, N).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    la = dtc * a.astype(f32)                    # log decay per step [b,c,q,h]
    L = jnp.cumsum(la, axis=2)                  # inclusive cumulative log decay
    Llast = L[:, :, -1]                         # [b,c,h]

    # intra-chunk (i >= j): y_ij = C_i·B_j * exp(L_i-L_j) * dt_j * x_j
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    dd = decay_dtype
    Ld = L.astype(dd)
    decay = jnp.exp(Ld[:, :, :, None, :] - Ld[:, :, None, :, :])  # [b,c,i,j,h]
    ii = jnp.arange(Q)
    mask = (ii[:, None] >= ii[None, :]).astype(dd)
    decay = decay * mask[None, None, :, :, None]
    xdt = (xc.astype(f32) * dtc[..., None])                     # [b,c,q,h,p]
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", G.astype(dd), decay,
                        xdt.astype(dd), preferred_element_type=f32)

    # chunk state contributions: sum_j exp(Llast-L_j) dt_j B_j ⊗ x_j
    w = jnp.exp(Llast[:, :, None, :] - L)                       # [b,c,q,h]
    cs = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, w * dtc, xc.astype(f32))

    def step(h, inp):
        cs_c, dec_c = inp                                       # [b,h,n,p], [b,h]
        h_prev = h
        h = dec_c[:, :, None, None] * h + cs_c
        return h, h_prev

    h0 = jnp.zeros((Bsz, H, N, P), f32)
    hT, h_prevs = jax.lax.scan(
        step, h0,
        (cs.transpose(1, 0, 2, 3, 4), jnp.exp(Llast).transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                  # [b,c,h,n,p]

    # inter-chunk: y_i += C_i · (exp(L_i) * h_in)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, h_prevs, jnp.exp(L))
    y = (y_diag + y_inter).reshape(Bsz, S, H, P)[:, :S0]
    return y.astype(xh.dtype), hT


def mamba2_forward(params, x, chunk: int = 128, return_state: bool = False,
                   decay_dtype=jnp.float32):
    """x [B,S,D] -> [B,S,D] (full-sequence training/prefill path)."""
    z = jnp.einsum("bsd,df->bsf", x, params["wz"])
    xb = jnp.einsum("bsd,df->bsf", x, params["wx"])
    xb = jax.nn.silu(_causal_conv(xb, params["conv_w"], params["conv_b"]))
    xb = lsc(xb, "batch", "seq", "ffn")
    B_ = x @ params["wB"]
    C_ = x @ params["wC"]
    H = params["a_log"].shape[0]
    P = xb.shape[-1] // H
    dt = jax.nn.softplus(
        (x @ params["wdt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xb.reshape(*xb.shape[:2], H, P)
    y, state = _ssd_chunked(xh, B_, C_, dt, a, chunk, decay_dtype=decay_dtype)
    y = y + xh * params["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(*xb.shape)
    y = rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, params["wo"])
    if return_state:
        # conv cache = last (W-1) x-branch inputs, pre-conv
        raw = jnp.einsum("bsd,df->bsf", x, params["wx"])
        W = params["conv_w"].shape[0]
        conv_cache = raw[:, -(W - 1):, :]
        return out, (state, conv_cache)
    return out


def mamba2_decode(params, x, state, conv_cache):
    """Single-step recurrence.  x [B,1,D]; state [B,H,N,P];
    conv_cache [B,W-1,Di] holds the previous pre-conv x-branch inputs."""
    f32 = jnp.float32
    z = jnp.einsum("bsd,df->bsf", x, params["wz"])[:, 0]
    raw = jnp.einsum("bsd,df->bsf", x, params["wx"])[:, 0]          # [B,Di]
    W = params["conv_w"].shape[0]
    window = jnp.concatenate([conv_cache, raw[:, None, :]], axis=1)  # [B,W,Di]
    xb = jax.nn.silu(jnp.einsum("bwf,wf->bf", window, params["conv_w"]) + params["conv_b"])
    new_conv_cache = window[:, 1:, :]
    B_ = (x[:, 0] @ params["wB"]).astype(f32)
    C_ = (x[:, 0] @ params["wC"]).astype(f32)
    H = params["a_log"].shape[0]
    P = xb.shape[-1] // H
    dt = jax.nn.softplus(
        (x[:, 0] @ params["wdt"]).astype(f32) + params["dt_bias"].astype(f32))  # [B,H]
    a = -jnp.exp(params["a_log"].astype(f32))
    xh = xb.reshape(-1, H, P).astype(f32)
    da = jnp.exp(dt * a)                                           # [B,H]
    state = da[:, :, None, None] * state + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, B_, xh)
    y = jnp.einsum("bn,bhnp->bhp", C_, state)
    y = y + xh * params["d_skip"].astype(f32)[None, :, None]
    y = y.reshape(xb.shape).astype(x.dtype)
    y = rms_norm(params["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bf,fd->bd", y, params["wo"])[:, None, :]
    return out, state, new_conv_cache
