"""Multiprocess shard runtime: TF-Worker shards as OS processes (§3.4, Fig 13).

``ProcessShardPool`` is the cross-interpreter sibling of
``ShardedWorkerPool``: each ``ShardWorker`` runs in its **own process** over
the durable ``FilePartitionedEventStore``, so pure-Python workloads scale
with cores instead of saturating one GIL (the threaded pool's ceiling — see
``benchmarks/sharded_load.py --mode=process``).  Crossing the interpreter
boundary replaces every in-memory shortcut of the threaded pool with its
real distributed-systems counterpart:

* **data plane** — events, commits and DLQ state flow through per-partition
  segment logs (file-locked per partition: the striped-lock design carried
  across processes) instead of shared ``StreamShard`` objects;
* **checkpoints** — each shard process appends context deltas to its own
  scope of the ``FileStateStore`` delta log; the pool folds all scopes into
  the compacted base at every ownership boundary;
* **control plane** — trigger management (add / enable / disable) is
  *broadcast over a command pipe* as serialized specs, mirroring the paper's
  trigger-API → worker path;
* **membership** — the same ``ConsumerGroup`` (consistent hashing with
  bounded loads), driven by the parent, with a two-phase rebalance: revoke
  moved partitions from their old owners (ack'd), fold checkpoint scopes,
  then grant — so a partition never has two live writers;
* **crashes** — ``crash_shard`` is a real ``SIGKILL``.  Recovery is §3.4
  verbatim: the replacement owner reloads trigger defs + last acknowledged
  checkpoints from disk and the bus redelivers everything uncommitted,
  including a batch torn mid-append (never acknowledged ⇒ truncated).

Start method: ``fork`` where available (fast; inherits registered
conditions/actions/pyfuncs), else ``spawn`` (``child_init`` and any custom
registrations must then be importable/picklable).  Event-id uniqueness
across forked processes is guaranteed by the per-process id prefix in
``repro.core.events``.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import signal
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional

from ..core.events import CloudEvent  # noqa: F401  (re-exported for callers)
from ..core.functions import FunctionBackend
from ..core.policy import REASON_DISABLED, CircuitBreaker
from ..core.statestore import FileStateStore
from ..core.triggers import Trigger
from ..core.worker import WorkerStats
from ..obs.metrics import empty_snapshot, fold_counters, merge_snapshot
from .group import ConsumerGroup
from .partitioned import FilePartitionedEventStore
from .pool import ShardWorker
from .replicate import ReplicaServer, ReplicationClient


def _stats_dict(worker) -> Dict[str, int]:
    d = worker.stats.snapshot()
    d["cpu_seconds"] = time.process_time()
    return d


def _metrics_dict(worker, store) -> Dict[str, Any]:
    """The shard's full observability snapshot, shipped over the command
    pipe: histogram registry + stats counters (``metrics_snapshot``) plus
    the shard's own segment-append accounting and a CPU gauge."""
    snap = worker.metrics_snapshot()
    ap = store.append_stats(worker.workflow)
    fold_counters(snap, {"tf_log_appends_total": ap["appends"]})
    snap["counters"]["tf_log_append_seconds_total"] = (
        snap["counters"].get("tf_log_append_seconds_total", 0)
        + ap["append_seconds"])
    snap["gauges"]["tf_cpu_seconds"] = time.process_time()
    # host-loss fault domain: writes this shard had fenced (a superseded
    # lease epoch) and the bytes it has shipped but not yet had acked
    if getattr(store, "lease_owner", None) is not None:
        fold_counters(snap, {"tf_fenced_writes_total": store.fenced_writes})
    rep = getattr(store, "_rep", None)
    if rep is not None:
        snap["gauges"]["tf_replication_lag_bytes"] = (
            snap["gauges"].get("tf_replication_lag_bytes", 0)
            + rep.replica_lag_bytes())
    return snap


def _shard_main(member: str, workflow: str, bus_root: str, state_root: str,
                num_partitions: int, conn, cfg: Dict[str, Any]) -> None:
    """Shard process entry point: build the stores/worker from disk, then
    loop — drain commands, run one batch, idle-wait on the pipe.  The final
    text of every reply carries ``member`` so the parent can assert it is
    talking to whom it thinks.

    KEDA-style scale-down (``idle_timeout``): a shard that processes nothing
    for the grace period announces ``("idle", ...)`` and exits cleanly
    (code 0) — the container-per-worker analogue of the threaded runner's
    idle drop.  Its partitions stay with the (dead) member until the parent's
    next ``reap()`` hands them to survivors — or, at scale-to-zero, until a
    later burst makes the autoscaler start fresh shards."""
    replica_addr = cfg.get("replica_addr")
    lease = bool(cfg.get("lease"))
    store = FilePartitionedEventStore(
        bus_root, num_partitions, fsync=cfg["fsync"],
        replicate_to=replica_addr, replicate_prefix="bus",
        lease_owner=member if lease else None,
        lease_ttl=cfg.get("lease_ttl", 30.0),
        event_codec=cfg.get("event_codec", "binary"))
    state_rep = None
    if replica_addr is not None:
        state_rep = ReplicationClient(replica_addr, state_root,
                                      prefix="state")
    state = FileStateStore(state_root, scope=member, replicator=state_rep)
    backend = FunctionBackend(store, inline=True)
    child_init = cfg.get("child_init")
    if child_init is not None:
        child_init(backend)
    tracer = None
    if cfg.get("trace"):
        # span segment: SIGKILL-durable sink under <root>/spans; spans flush
        # with the worker's checkpoint, open records immediately
        from ..core.eventstore import SegmentLog
        from ..obs.trace import SpanCollector, Tracer
        os.makedirs(cfg["trace_dir"], exist_ok=True)
        seg = SegmentLog(
            os.path.join(cfg["trace_dir"], "spans.%s.jsonl" % member),
            fsync=cfg["fsync"])
        sample = 1.0 if cfg["trace"] == "full" else cfg.get("trace_sample", 0.1)
        tracer = Tracer(sample=sample, collector=SpanCollector(segment=seg),
                        tag=member)
    worker = ShardWorker(
        member, workflow, store, state, backend,
        batch_size=cfg["batch_size"], commit_policy=cfg["commit_policy"],
        keep_event_log=False, timers=None, partitions=(),
        batch_plane=cfg["batch_plane"], action_plane=cfg["action_plane"],
        metrics=cfg.get("metrics", True), tracer=tracer,
    )
    conn.send(("ready", member))
    poll = cfg["poll"]
    idle_timeout = cfg.get("idle_timeout")
    last_active = time.monotonic()
    notified_finish = False
    try:
        while True:
            while conn.poll(0):
                msg = conn.recv()
                op = msg[0]
                if op == "assign":
                    parts, gen = tuple(msg[1]), msg[2]
                    with worker.lock:
                        dropped: tuple = ()
                        if worker.partitions != parts:
                            dropped = tuple(
                                set(worker.partitions) - set(parts))
                            worker.partitions = parts
                            worker.rebalance_reset()
                    if lease:
                        # sanctioned ownership change: release what moved
                        # away, (re-)acquire what was granted — the epoch
                        # bump fences any zombie writer and clears this
                        # member's own fence latches for the partitions
                        for p in sorted(dropped):
                            store.release_partition_lease(workflow, p)
                        if parts:
                            store.reacquire_partition_leases(workflow, parts)
                    # fresh ownership restarts the idle clock: the grace
                    # period measures inactivity *while serving*, not time
                    # spent waiting out a rebalance
                    last_active = time.monotonic()
                    conn.send(("assigned", member, gen))
                elif op == "add_trigger":
                    worker.add_trigger(Trigger.from_dict(msg[1]), persist=False)
                    conn.send(("ok", member))
                elif op == "enable":
                    if msg[1] in worker.triggers:
                        worker.set_trigger_enabled(msg[1], msg[2])
                    conn.send(("ok", member))
                elif op == "stats":
                    conn.send(("stats", member, _stats_dict(worker)))
                elif op == "metrics":
                    conn.send(("metrics", member, _metrics_dict(worker, store)))
                elif op == "ping":
                    conn.send(("pong", member))
                elif op == "stop":
                    if tracer is not None:
                        tracer.flush()
                    if replica_addr is not None:
                        # bound the replica's staleness at a clean exit;
                        # SIGKILL keeps whatever lag was in flight — that
                        # is the bounded-lag window recovery tolerates
                        store.drain_replication(5.0)
                        state_rep.drain(5.0)
                    conn.send(("stopped", member, _stats_dict(worker)))
                    return
            try:
                n = worker.run_once() if worker.partitions else 0
            except Exception as exc:  # noqa: BLE001 - a failed batch is a crash
                # Nothing from the failed batch was checkpointed or
                # committed (the exception interrupted _checkpoint at the
                # latest), so dying here leaves the store in the ordinary
                # crash state: the parent reaps the non-zero exit and the
                # partitions' next owner replays the uncommitted events.
                traceback.print_exc()
                try:
                    conn.send(("failed", member, repr(exc)))
                except Exception:  # noqa: BLE001
                    # tfcheck: allow[seam-safety] best-effort death notice on a dying pipe; SystemExit(1) below is the real signal
                    pass
                raise SystemExit(1)
            if worker.finished and not notified_finish:
                notified_finish = True
                conn.send(("finished", member, worker.result))
            if n:
                last_active = time.monotonic()
            else:
                if idle_timeout is not None and \
                        time.monotonic() - last_active > idle_timeout:
                    # scale-to-zero: announce the clean exit (best effort —
                    # the parent classifies by exit code 0 regardless) and go
                    if tracer is not None:
                        tracer.flush()
                    if replica_addr is not None:
                        store.drain_replication(5.0)
                        state_rep.drain(5.0)
                    try:
                        conn.send(("idle", member, _stats_dict(worker)))
                    except (BrokenPipeError, OSError):  # pragma: no cover
                        pass
                    return
                conn.poll(poll)  # idle sleep; a command wakes us early
    except (EOFError, BrokenPipeError):  # parent is gone: nothing to serve
        return


class _ProcShard:
    __slots__ = ("member", "proc", "conn", "alive", "partitions",
                 "final_stats", "finished", "result", "exit_reason")

    def __init__(self, member: str, proc, conn) -> None:
        self.member = member
        self.proc = proc
        self.conn = conn
        self.alive = True
        self.partitions: tuple = ()
        self.final_stats: Optional[Dict[str, int]] = None
        self.finished = False
        self.result: Any = None
        # why the process left ("idle" | "stopped" | "error" | None while
        # running) — from its last pipe message or, failing that, its exit
        # code; ``reap()`` folds these into the autoscaler's accounting
        self.exit_reason: Optional[str] = None


class _ProcWorkflow:
    __slots__ = ("group", "shards", "next_id", "crashes", "rebalances",
                 "triggers", "finished", "result", "unreaped", "retired_stats",
                 "breaker", "node_recoveries", "recovery_seconds",
                 "unreported_recoveries")

    def __init__(self, num_partitions: int,
                 breaker: Optional[CircuitBreaker] = None) -> None:
        self.group = ConsumerGroup(num_partitions)
        self.shards: Dict[str, _ProcShard] = {}
        self.next_id = 0
        self.crashes = 0
        self.rebalances = 0
        self.triggers: Dict[str, Dict[str, Any]] = {}  # parent spec cache
        self.finished = False
        self.result: Any = None
        # departures retired outside reap() (_observe_death during a
        # broadcast/rebalance), by exit reason — folded into the next reap()
        # report exactly once so the autoscaler's accounting sees them
        self.unreaped: List[str] = []
        # summed final_stats of departed-and-dropped shards: scale-to-zero
        # cycles must not grow wf.shards without bound, but the workflow's
        # lifetime totals (events_processed, fires, …) must survive the drop
        self.retired_stats: Dict[str, int] = {}
        # crash-loop breaker: consecutive-crash streak gates start_shards
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        # host-loss recoveries (recover_host_loss): lifetime count, summed
        # wall-clock seconds, and the not-yet-reaped delta the autoscaler's
        # accounting drains exactly once
        self.node_recoveries = 0
        self.recovery_seconds = 0.0
        self.unreported_recoveries = 0

    def fold_retired(self, shard: _ProcShard) -> None:
        if shard.final_stats:
            WorkerStats.fold(self.retired_stats, shard.final_stats)


class ProcessShardPool:
    """Runs N ShardWorker *processes* per workflow over the file-backed bus.

    ``root`` holds the whole deployment: ``<root>/bus`` (partitioned event
    segments) and ``<root>/state`` (workflow/trigger/context database).  A
    pool constructed over an existing root *recovers* it — streams, trigger
    defs and checkpoints are all on disk.

    ``fsync=False`` keeps every durability property against process
    crashes/SIGKILL (the page cache survives) and trades only power-loss
    durability for a large cut in append latency — the Kafka default-flush
    analogy.  Crash tests run with the default ``fsync=True``.
    """

    def __init__(
        self,
        root: str,
        num_partitions: int = 8,
        batch_size: int = 512,
        commit_policy: str = "every_batch",
        poll: float = 0.002,
        fsync: bool = True,
        batch_plane: bool = True,
        action_plane: bool = True,
        start_method: Optional[str] = None,
        child_init: Optional[Callable] = None,
        command_timeout: float = 30.0,
        metrics: bool = True,
        trace: Optional[str] = None,
        trace_sample: float = 0.1,
        breaker: Optional[Dict[str, Any]] = None,
        replicate: bool = False,
        replica_root: Optional[str] = None,
        lease: bool = False,
        lease_ttl: float = 30.0,
        event_codec: str = "binary",
    ) -> None:
        # ``command_timeout`` bounds every command-pipe round-trip.  Shard
        # processes service the pipe between batches, so it must exceed the
        # worst-case batch (batch_size × the slowest action) — a busy shard
        # that misses the deadline is treated as hung and SIGKILLed.  Size
        # batches (or raise this) accordingly for slow-action workloads.
        self.root = root
        self.bus_root = os.path.join(root, "bus")
        self.state_root = os.path.join(root, "state")
        self._num_partitions = num_partitions  # bus default; see num_partitions()
        # -- host-loss fault domain -------------------------------------------
        # replicate=True stands up a ReplicaServer under <root>/replica (or
        # ``replica_root`` — on a real deployment, another host) and ships
        # every segment mutation there: the parent's publishes, each shard
        # process's commits/DLQ/checkpoints.  The replica mirrors the whole
        # deployment layout (replica/bus/..., replica/state/...), so
        # ``recover_host_loss`` can rebuild a lost segment root from it.
        # lease=True arms lease-fenced ownership in the shard processes.
        self.replica_root = replica_root or os.path.join(root, "replica")
        self.replica_server: Optional[ReplicaServer] = None
        self._rep_addr = None
        if replicate:
            self.replica_server = ReplicaServer(self.replica_root)
            self._rep_addr = self.replica_server.address
        self.event_store = FilePartitionedEventStore(
            self.bus_root, num_partitions, fsync=fsync,
            replicate_to=self._rep_addr, replicate_prefix="bus",
            event_codec=event_codec)
        self.state_store = FileStateStore(
            self.state_root,
            replicator=(ReplicationClient(self._rep_addr, self.state_root,
                                          prefix="state")
                        if self._rep_addr is not None else None))
        # trace: None (off) | "sampled" (trace_sample of new roots) |
        # "full" (every fire).  Span segments land under <root>/spans,
        # one SIGKILL-durable file per shard process, stitched by
        # trace_spans()/scripts/trace_report.py.
        self.trace_dir = os.path.join(root, "spans")
        if trace:
            os.makedirs(self.trace_dir, exist_ok=True)
        self._cfg: Dict[str, Any] = {
            "batch_size": batch_size, "commit_policy": commit_policy,
            "poll": poll, "fsync": fsync, "batch_plane": batch_plane,
            "action_plane": action_plane, "child_init": child_init,
            "idle_timeout": None,
            "metrics": metrics, "trace": trace, "trace_sample": trace_sample,
            "trace_dir": self.trace_dir,
            "replica_addr": self._rep_addr, "lease": lease,
            "lease_ttl": lease_ttl, "event_codec": event_codec,
        }
        self.metrics_enabled = metrics
        self.command_timeout = command_timeout
        # CircuitBreaker kwargs applied to every workflow's crash-loop
        # breaker (threshold / backoff_* / cooldown — see core.policy).
        self.breaker_conf = dict(breaker) if breaker else {}
        if start_method is None:
            start_method = ("fork" if "fork" in mp.get_all_start_methods()
                            else "spawn")
        self.start_method = start_method
        self._mp = mp.get_context(start_method)
        self._lock = threading.RLock()
        self._wfs: Dict[str, _ProcWorkflow] = {}

    # -- workflow / trigger management (the Fig. 1 control plane) --------------
    def _wf(self, workflow: str) -> _ProcWorkflow:
        wf = self._wfs.get(workflow)
        n = self.event_store.num_partitions_for(workflow)
        if wf is None:
            wf = self._wfs.setdefault(
                workflow, _ProcWorkflow(n, CircuitBreaker(**self.breaker_conf)))
        elif wf.group.num_partitions != n:
            # a per-workflow partition pin landed after this group was sized
            # (e.g. add_trigger before create_workflow(num_partitions=...)):
            # resize while empty; live members mean the widths diverged
            if wf.group.members():
                raise ValueError(
                    "workflow %r is sharded over %d partitions but the store "
                    "now pins %d" % (workflow, wf.group.num_partitions, n))
            wf.group = ConsumerGroup(n)
        return wf

    def num_partitions(self, workflow: str) -> int:
        """The workflow's pinned partition count (``ScalablePool``) — the
        hard shard cap the autoscaler must respect per workflow."""
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is not None:
                return wf.group.num_partitions
        return self.event_store.num_partitions_for(workflow)

    def create_workflow(self, workflow: str,
                        meta: Optional[Dict[str, Any]] = None,
                        num_partitions: Optional[int] = None) -> None:
        """``num_partitions`` pins a per-workflow partition count (written to
        the stream's ``stream.json``); create the workflow before starting
        shards or publishing from other processes, so every store instance
        routes its subjects identically."""
        self.event_store.create_stream(workflow, num_partitions=num_partitions)
        m = {"status": "created"}
        m.update(meta or {})
        self.state_store.put_workflow(workflow, m)
        with self._lock:
            self._wf(workflow)

    def add_trigger(self, workflow: str, trigger: Trigger) -> str:
        """Persist the spec (restart/bootstrap source of truth), then
        broadcast it to every live shard process over the command pipe."""
        spec = trigger.to_dict()
        with self._lock:
            wf = self._wf(workflow)
            self.state_store.put_trigger(workflow, trigger.trigger_id, spec)
            wf.triggers[trigger.trigger_id] = spec
            for shard in self._live(wf):
                if self._request(wf, shard, ("add_trigger", spec), "ok") is None:  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                    self._observe_death(workflow, wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
        return trigger.trigger_id

    def set_trigger_enabled(self, workflow: str, trigger_id: str,
                            enabled: bool) -> None:
        """Broadcast the flip; re-enabling also redrives the DLQ of the
        trigger's subject partitions (§3.4) through the shared bus files —
        the owning shards pick the requeued events up on their next sync."""
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return
            for shard in self._live(wf):
                if self._request(wf, shard,  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                                 ("enable", trigger_id, enabled), "ok") is None:
                    self._observe_death(workflow, wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
            if enabled:
                spec = wf.triggers.get(trigger_id) or \
                    self.state_store.get_triggers(workflow).get(trigger_id, {})
                subjects = spec.get("activation_events", ())
                if subjects:
                    parts = {self.event_store.partition_for(s, workflow)
                             for s in subjects}
                    # only ``disabled`` quarantines come back; poison:* stays
                    # put until an operator redrives explicitly
                    self.event_store.redrive_partitions(
                        workflow, parts, reasons=(REASON_DISABLED,))

    def publish(self, workflow: str, event: CloudEvent) -> None:
        self.event_store.publish(workflow, event)

    def publish_batch(self, workflow: str, events) -> None:
        self.event_store.publish_batch(workflow, events)

    # -- shard lifecycle --------------------------------------------------------
    def _live(self, wf: _ProcWorkflow) -> List[_ProcShard]:
        return [s for s in wf.shards.values() if s.alive]

    def shard_ids(self, workflow: str) -> List[str]:
        with self._lock:
            wf = self._wfs.get(workflow)
            return [s.member for s in self._live(wf)] if wf else []

    def shard_count(self, workflow: str) -> int:
        return len(self.shard_ids(workflow))

    def breaker_of(self, workflow: str) -> CircuitBreaker:
        """The workflow's crash-loop breaker (autoscaler gate + tests)."""
        with self._lock:
            return self._wf(workflow).breaker

    def live_shard_count(self, workflow: str) -> int:
        """Shard processes that are actually running right now (an idle-exited
        or crashed child stops counting the moment it dies, even before
        ``reap()`` retires its membership) — the autoscaler's Fig-8 signal."""
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return 0
            return sum(1 for s in wf.shards.values()
                       if s.alive and s.proc.is_alive())

    def start_shards(self, workflow: str, count: int,
                     idle_timeout: Optional[float] = None,
                     ready_timeout: float = 30.0) -> List[str]:
        """Ensure ``count`` live shard processes serve ``workflow``.

        ``idle_timeout`` arms KEDA-style scale-down in every shard started by
        this call: a child that processes nothing for that grace period exits
        cleanly (code 0) and is reaped as a scale-down, not a crash."""
        with self._lock:
            wf = self._wf(workflow)
            cfg = self._cfg
            if idle_timeout is not None:
                cfg = dict(cfg)
                cfg["idle_timeout"] = idle_timeout
            fresh: List[_ProcShard] = []
            need = count - len(self._live(wf))
            granted = wf.breaker.allow_start(need) if need > 0 else 0
            if granted < max(0, need):
                # crash-loop breaker: a crash streak makes fresh starts wait
                # out an exponential backoff; past the threshold the circuit
                # opens until a cooldown admits one half-open probe
                print("[proc-pool] circuit breaker for workflow %r (%s, "
                      "streak=%d): granting %d/%d shard start(s)"
                      % (workflow, wf.breaker.state, wf.breaker.streak,
                         granted, need))
            while len(fresh) < granted:
                member = "proc-%d" % wf.next_id
                wf.next_id += 1
                parent_conn, child_conn = self._mp.Pipe()
                proc = self._mp.Process(
                    target=_shard_main,
                    args=(member, workflow, self.bus_root, self.state_root,
                          self._num_partitions, child_conn, cfg),
                    name="tf-%s-%s" % (workflow, member), daemon=True)
                proc.start()
                child_conn.close()
                fresh.append(_ProcShard(member, proc, parent_conn))
            for shard in fresh:
                wf.shards[shard.member] = shard
                if self._await(wf, shard, "ready", ready_timeout) is None:  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                    self._observe_death(workflow, wf, shard, rebalance=False)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
            joined = False
            for shard in fresh:
                if shard.alive:
                    wf.group.join(shard.member)
                    joined = True
            if joined:
                self._rebalance(workflow, wf)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
            return [s.member for s in self._live(wf)]

    def remove_shard(self, workflow: str, member: str) -> None:
        """Graceful leave: drain-stop the process, fold its checkpoint scope,
        hand its partitions to the rest."""
        with self._lock:
            wf = self._wfs.get(workflow)
            shard = wf.shards.get(member) if wf else None
            if shard is None:
                return
            self._stop_shard(wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
            wf.group.leave(member)
            wf.breaker.record_clean()
            self._rebalance(workflow, wf)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout

    def crash_shard(self, workflow: str, member: str) -> None:
        """A real crash: SIGKILL the shard process mid-whatever-it-was-doing.
        Nothing it had not checkpointed/committed survives; the group
        reassigns its partitions and the bus redelivers every uncommitted
        event to the new owners (§3.4 / Fig 13)."""
        with self._lock:
            wf = self._wfs.get(workflow)
            shard = wf.shards.get(member) if wf else None
            if shard is None or not shard.alive:
                return
            if shard.proc.is_alive():
                os.kill(shard.proc.pid, signal.SIGKILL)
            shard.proc.join(timeout=10.0)
            shard.alive = False
            shard.exit_reason = "error"
            shard.conn.close()
            wf.crashes += 1
            wf.breaker.record_crash()
            wf.group.leave(member)
            self._rebalance(workflow, wf)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout

    def recover_host_loss(self, workflow: str, count: Optional[int] = None,
                          ready_timeout: float = 30.0) -> float:
        """Bounded-time recovery from losing the node that served
        ``workflow`` — host *and* local segment root (the disk is gone, not
        just the processes).  The sequence:

        1. SIGKILL whatever shard processes remain (their working set
           vanished from under them).  Node loss is not a crash loop: the
           breaker is NOT fed, so the restart below is not backoff-gated —
           but an already-open breaker still gates it, by design (a workflow
           mid-quarantine does not get resurrected by a host failover).
        2. Rehydrate the workflow's bus partition files from the replica
           root (``restore_from_replica`` — the ordinary torn-tail-tolerant
           replay, fed from the replica's bytes).
        3. Restart ``count`` shards (default: as many as were live).  The
           fresh children force-acquire the partition leases on their first
           assignment — the epoch bump fences any zombie writer that
           survived the "lost" host.

        Returns wall-clock recovery seconds (also ``tf_recovery_seconds``)."""
        if self.replica_server is None:
            raise RuntimeError(
                "recover_host_loss requires the pool to be constructed with "
                "replicate=True (there is no replica to recover from)")
        t0 = time.perf_counter()
        with self._lock:
            wf = self._wf(workflow)
            want = count if count is not None else max(1, len(self._live(wf)))
            for shard in list(wf.shards.values()):
                if not shard.alive:
                    continue  # already departed: reap() accounts for it
                self._drain_final(wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                if shard.proc.is_alive():
                    os.kill(shard.proc.pid, signal.SIGKILL)
                shard.proc.join(timeout=10.0)
                shard.alive = False
                shard.exit_reason = "host-loss"
                shard.conn.close()
                wf.group.leave(shard.member)
                wf.unreaped.append("host-loss")
                wf.fold_retired(shard)
                wf.shards.pop(shard.member, None)
            self.event_store.restore_from_replica(
                workflow, os.path.join(self.replica_root, "bus"))
            wf.node_recoveries += 1
            wf.unreported_recoveries += 1
        self.start_shards(workflow, want, ready_timeout=ready_timeout)
        seconds = time.perf_counter() - t0
        with self._lock:
            wf.recovery_seconds += seconds
        return seconds

    def replica_lag(self, workflow: str) -> Dict[int, int]:
        """True per-partition replication deficit in bytes: local segment
        sizes minus the replica's — across ALL writers (parent publishes and
        every shard process), unlike the per-client ``replica_lags`` view.
        Empty when replication is off."""
        out: Dict[int, int] = {}
        if self.replica_server is None:
            return out
        d = os.path.join(self.bus_root, workflow.replace("/", "_"))
        rd = os.path.join(self.replica_root, "bus",
                          workflow.replace("/", "_"))
        if not os.path.isdir(d):
            return out
        for fn in sorted(os.listdir(d)):
            if fn.rpartition(".")[2] not in ("log", "committed", "dlq"):
                continue
            if not (fn.startswith("p") and fn[1:5].isdigit()):
                continue
            try:
                local = os.path.getsize(os.path.join(d, fn))
            except OSError:
                local = 0
            try:
                remote = os.path.getsize(os.path.join(rd, fn))
            except OSError:
                remote = 0
            if local > remote:
                p = int(fn[1:5])
                out[p] = out.get(p, 0) + (local - remote)
        return out

    def reap(self, workflow: str) -> Dict[str, Any]:
        """Fold in shards whose process died on its own — idle scale-down,
        workflow end, or a genuine crash (SIGKILL, OOM, failed batch).
        Mirrors the thread pool's ``ScalablePool`` accounting:
        ``{"reaped": n, "crashed": m, "reasons": {reason: count}}``.

        Classification is by the child's *recorded exit reason* (its last
        pipe message — ``idle``/``stopped``/``failed``), falling back to the
        exit code: 0 is a clean departure, anything else (including a signal
        death's negative code) is a crash."""
        reaped = crashed = 0
        reasons: Dict[str, int] = {}
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return {"reaped": 0, "crashed": 0, "reasons": {},
                        "node_recoveries": 0}
            # host-loss recoveries since the last reap: the restart storm
            # they caused is deliberate (not a crash loop), so the
            # autoscaler accounts them separately
            recoveries = wf.unreported_recoveries
            wf.unreported_recoveries = 0
            # departures _observe_death already retired (their wf.crashes
            # were counted there; only the report entries are pending)
            for reason in wf.unreaped:
                reaped += 1
                reasons[reason] = reasons.get(reason, 0) + 1
                if reason == "error":
                    crashed += 1
            wf.unreaped = []
            dead = [s for s in wf.shards.values()
                    if s.alive and not s.proc.is_alive()]
            for shard in dead:
                self._drain_final(wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                shard.alive = False
                shard.conn.close()
                wf.group.leave(shard.member)
                reaped += 1
                reason = shard.exit_reason
                if reason is None:
                    reason = "stopped" if shard.proc.exitcode == 0 else "error"
                    shard.exit_reason = reason
                reasons[reason] = reasons.get(reason, 0) + 1
                if reason == "error":
                    crashed += 1
                    wf.crashes += 1
                    wf.breaker.record_crash()
                else:
                    wf.breaker.record_clean()
                # drop the corpse (scale-to-zero cycles are unbounded;
                # wf.shards must not be) but keep its lifetime totals
                wf.fold_retired(shard)
                wf.shards.pop(shard.member, None)
            if dead:
                self._rebalance(workflow, wf)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
        return {"reaped": reaped, "crashed": crashed, "reasons": reasons,
                "node_recoveries": recoveries}

    def stop(self, workflow: str) -> None:
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return
            for shard in self._live(wf):
                self._stop_shard(wf, shard)  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                # the member is gone for good: without the leave, a later
                # start_shards would assign partitions to a dead member and
                # the workflow would stall forever
                wf.group.leave(shard.member)
            self.state_store.compact(workflow)

    def stop_all(self) -> None:
        for workflow in list(self._wfs.keys()):
            self.stop(workflow)

    def close_replication(self) -> None:
        """Tear down the replication plane (tests/soaks; the threads are
        daemons, so skipping this just leaves idle sockets until exit)."""
        rep = getattr(self.event_store, "_rep", None)
        if rep is not None:
            rep.drain(2.0)
            rep.close()
        if self.state_store.replicator is not None:
            self.state_store.replicator.drain(2.0)
            self.state_store.replicator.close()
        if self.replica_server is not None:
            self.replica_server.close()

    def _stop_shard(self, wf: _ProcWorkflow, shard: _ProcShard) -> None:
        reply = self._request(wf, shard, ("stop",), "stopped", timeout=10.0)
        if reply is not None:
            shard.final_stats = reply[2]
            shard.exit_reason = "stopped"
        shard.proc.join(timeout=10.0)
        if shard.proc.is_alive():  # refused to die: escalate
            os.kill(shard.proc.pid, signal.SIGKILL)
            shard.proc.join(timeout=10.0)
            shard.exit_reason = "error"
        shard.alive = False
        shard.conn.close()

    def _observe_death(self, workflow: str, wf: _ProcWorkflow,
                       shard: _ProcShard, rebalance: bool = True) -> None:
        """A shard stopped answering: confirm it is gone and rebalance.
        A child that managed a clean last word (``idle``/``stopped``) before
        the pipe broke — e.g. an idle-exit racing a broadcast — is a clean
        departure, not a crash."""
        self._drain_final(wf, shard)
        if shard.proc.is_alive():
            os.kill(shard.proc.pid, signal.SIGKILL)
        shard.proc.join(timeout=10.0)
        shard.alive = False
        shard.conn.close()
        if shard.exit_reason not in ("idle", "stopped"):
            shard.exit_reason = "error"
            wf.crashes += 1
            wf.breaker.record_crash()
        else:
            wf.breaker.record_clean()
        wf.unreaped.append(shard.exit_reason)
        wf.fold_retired(shard)
        wf.shards.pop(shard.member, None)
        wf.group.leave(shard.member)
        if rebalance:
            self._rebalance(workflow, wf)

    # -- rebalance (two-phase, ack'd) -------------------------------------------
    def _rebalance(self, workflow: str, wf: _ProcWorkflow,
                   _depth: int = 0) -> None:
        """Never let a partition have two live writers:

        1. *Revoke*: shrink every continuing owner to the partitions it
           keeps, and wait for each ack (the child resets volatile state to
           its last checkpoint before answering).
        2. *Fold*: compact every checkpoint scope into the base — after
           this, any scope may legally write any trigger.
        3. *Grant*: send the full new assignment (ack'd as well, so callers
           returning from membership changes see a settled group).

        A shard found dead mid-rebalance leaves the group and the whole
        pass re-runs against the shrunken membership, so its partitions are
        granted to survivors instead of dangling until the next change."""
        if _depth == 0:
            wf.rebalances += 1
        assignment = wf.group.assignment()
        lost = False
        for shard in self._live(wf):
            target = set(assignment.get(shard.member, ()))
            retained = tuple(sorted(set(shard.partitions) & target))
            if retained != shard.partitions:
                if self._request(wf, shard, ("assign", retained, -1),
                                 "assigned") is None:
                    self._observe_death(workflow, wf, shard, rebalance=False)
                    lost = True
                    continue
                shard.partitions = retained
        self.state_store.compact(workflow)
        gen = wf.group.generation
        for shard in self._live(wf):
            target = tuple(sorted(assignment.get(shard.member, ())))
            if target != shard.partitions:
                if self._request(wf, shard, ("assign", target, gen),
                                 "assigned") is None:
                    self._observe_death(workflow, wf, shard, rebalance=False)
                    lost = True
                    continue
                shard.partitions = target
        if lost and _depth < len(wf.shards) + 1:
            self._rebalance(workflow, wf, _depth + 1)

    # -- request/reply over the command pipe -------------------------------------
    def _absorb(self, wf: _ProcWorkflow, shard: _ProcShard, msg) -> None:
        if msg[0] == "finished":
            shard.finished = True
            shard.result = msg[2]
            wf.finished = True
            wf.result = msg[2]
        elif msg[0] == "stats":
            shard.final_stats = msg[2]
        elif msg[0] == "metrics":
            pass  # stale scrape reply — nothing to keep
        elif msg[0] == "idle":
            # the child's goodbye before a clean scale-to-zero exit
            shard.exit_reason = "idle"
            shard.final_stats = msg[2]
        elif msg[0] == "failed":
            shard.exit_reason = "error"

    def _drain_final(self, wf: _ProcWorkflow, shard: _ProcShard) -> None:
        """Absorb a dead (or dying) shard's last words so its departure is
        classified by what it *said*, not only by its exit code."""
        try:
            while shard.conn.poll(0):
                self._absorb(wf, shard, shard.conn.recv())
        except (EOFError, BrokenPipeError, OSError):
            pass

    def _await(self, wf: _ProcWorkflow, shard: _ProcShard, op: str,
               timeout: Optional[float] = None):
        """Wait for a reply of type ``op``, absorbing unsolicited messages
        (``finished`` notifications, stale replies).  None ⇒ shard is gone."""
        deadline = time.monotonic() + (timeout or self.command_timeout)
        try:
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not shard.conn.poll(remaining):
                    return None
                msg = shard.conn.recv()
                if msg[0] == op:
                    return msg
                self._absorb(wf, shard, msg)
        except (EOFError, BrokenPipeError, OSError):
            return None

    def _request(self, wf: _ProcWorkflow, shard: _ProcShard, msg, reply_op: str,
                 timeout: Optional[float] = None):
        if not shard.alive:
            return None
        try:
            shard.conn.send(msg)
        except (BrokenPipeError, OSError):
            return None
        return self._await(wf, shard, reply_op, timeout)

    # -- observability -----------------------------------------------------------
    def lag(self, workflow: str) -> int:
        return self.event_store.lag(workflow)

    def _stats(self, workflow: str) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return out
            for member, shard in wf.shards.items():
                if shard.alive:
                    reply = self._request(wf, shard, ("stats",), "stats")  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                    if reply is not None:
                        out[member] = reply[2]
                        continue
                if shard.final_stats is not None:
                    out[member] = shard.final_stats
        return out

    def _retired_stat(self, workflow: str, key: str) -> int:
        with self._lock:
            wf = self._wfs.get(workflow)
            return wf.retired_stats.get(key, 0) if wf is not None else 0

    def total_events_processed(self, workflow: str) -> int:
        return self._retired_stat(workflow, "events_processed") + sum(
            s.get("events_processed", 0)
            for s in self._stats(workflow).values())

    def total_fires(self, workflow: str) -> int:
        return self._retired_stat(workflow, "fires") + sum(
            s.get("fires", 0) for s in self._stats(workflow).values())

    def trigger_context(self, workflow: str, trigger_id: str) -> Dict[str, Any]:
        """The trigger's last *acknowledged checkpoint* (base + all scope
        logs) — the durable truth a replacement owner would recover."""
        return self.state_store.get_contexts(workflow).get(trigger_id, {})

    def obs_snapshot(self, workflow: str) -> Dict[str, Any]:
        """Aggregate metrics snapshot across shard *processes*: each live
        shard is scraped over the command pipe (a shard that misses the
        deadline is simply skipped — scrapes never kill shards), retired
        shards contribute their folded exit stats, and the parent adds its
        own membership counters.  Same shape as the thread pool's
        ``obs_snapshot``, so ``merge_snapshot`` composes the two runtimes."""
        snap = empty_snapshot()
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is None:
                return snap
            for shard in wf.shards.values():
                if shard.alive:
                    reply = self._request(wf, shard, ("metrics",), "metrics",  # tfcheck: allow[lock-discipline] serialized control plane; waits bounded by command_timeout
                                          timeout=5.0)
                    if reply is not None:
                        merge_snapshot(snap, reply[2])
                elif shard.final_stats:
                    # stopped but not yet reaped/dropped: its exit stats are
                    # the counters' last word (same rule as ``_stats``)
                    fold_counters(snap, {
                        "tf_%s_total" % k: v
                        for k, v in shard.final_stats.items()
                        if k in WorkerStats.FIELDS})
            fold_counters(snap, {
                "tf_%s_total" % k: v for k, v in wf.retired_stats.items()
                if k in WorkerStats.FIELDS})
            breaker = wf.breaker.snapshot()
            fold_counters(snap, {"tf_rebalance_total": wf.rebalances,
                                 "tf_shard_failures_total": wf.crashes,
                                 "tf_circuit_open_total":
                                     breaker["opened_total"],
                                 "tf_node_recoveries_total":
                                     wf.node_recoveries})
            g = snap["gauges"]
            g["tf_restart_backoff_seconds"] = (
                g.get("tf_restart_backoff_seconds", 0.0)
                + breaker["restart_backoff_seconds"])
            g["tf_recovery_seconds"] = (
                g.get("tf_recovery_seconds", 0.0) + wf.recovery_seconds)
            rep = getattr(self.event_store, "_rep", None)
            if rep is not None:
                # the parent's own unacked publishes (shard lag arrives via
                # the scraped child snapshots above)
                g["tf_replication_lag_bytes"] = (
                    g.get("tf_replication_lag_bytes", 0)
                    + rep.replica_lag_bytes())
        return snap

    def trace_spans(self, workflow: Optional[str] = None) -> List[dict]:
        """Stitched span records from every shard's span segment (one file
        per shard process under ``<root>/spans``), deduplicated by span id —
        completed records win over their open (pre-crash) twins."""
        from ..obs.trace import load_spans, stitch_spans
        return stitch_spans(load_spans([self.trace_dir]))

    def metrics(self, workflow: str) -> Dict[str, Any]:
        with self._lock:
            wf = self._wfs.get(workflow)
            shards = self._live(wf) if wf else []
            out = {
                "shards": len(shards),
                "crashes": wf.crashes if wf else 0,
                "rebalances": wf.rebalances if wf else 0,
                "node_recoveries": wf.node_recoveries if wf else 0,
                "breaker": wf.breaker.snapshot() if wf else {},
                "generation": wf.group.generation if wf else 0,
                "assignment": {s.member: list(s.partitions) for s in shards},
                "partition_lags": self.event_store.partition_lags(workflow),
                "commit_offsets": self.event_store.commit_offsets(workflow),
                "total_lag": self.event_store.lag(workflow),
            }
        out["obs"] = self.obs_snapshot(workflow)
        return out

    def result(self, workflow: str) -> Any:
        with self._lock:
            wf = self._wfs.get(workflow)
            if wf is not None and wf.finished:
                return wf.result
        meta = self.state_store.get_workflow(workflow) or {}
        return meta.get("result")

    def wait_drained(self, workflow: str, timeout: float = 60.0,
                     poll: float = 0.02) -> None:
        """Block until every published event is committed (lag 0).  The
        multiprocess analogue of the thread pool's ``drive`` exit condition.
        Each poll also reaps shards whose process died on its own (a failed
        batch exits non-zero), so their partitions rebalance to survivors
        instead of stalling the drain until the timeout."""
        deadline = time.monotonic() + timeout
        while self.event_store.lag(workflow) > 0:
            self.reap(workflow)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    "workflow %r did not drain: " % workflow
                    + self.failure_diagnostics(workflow))
            time.sleep(poll)

    def failure_diagnostics(self, workflow: str) -> str:
        """One-line triage string for drain timeouts: per-partition lag, DLQ
        breakdown by reason, live shard count and breaker state."""
        try:
            lag_vec = self.event_store.partition_lags(workflow)
        except Exception:  # noqa: BLE001 - diagnostics must never raise
            lag_vec = []
        lags = lag_vec if isinstance(lag_vec, dict) else dict(enumerate(lag_vec))
        try:
            dlq = self.event_store.dlq_by_reason(workflow)
        except Exception:  # noqa: BLE001
            dlq = {}
        with self._lock:
            wf = self._wfs.get(workflow)
            breaker = wf.breaker.snapshot() if wf else {}
            recoveries = wf.node_recoveries if wf else 0
        try:
            rep_lag = self.replica_lag(workflow)
        except Exception:  # noqa: BLE001
            rep_lag = {}
        try:
            leases = self.event_store.lease_holders(workflow)
        except Exception:  # noqa: BLE001
            leases = {}
        return (f"lag={sum(lags.values())} "
                f"partition_lags={ {p: n for p, n in lags.items() if n} } "
                f"dlq_by_reason={dlq} "
                f"live_shards={self.live_shard_count(workflow)} "
                f"breaker={breaker} "
                f"replica_lag={rep_lag} "
                f"leases={leases} "
                f"node_recoveries={recoveries}")
