"""Partitioned event bus (paper §4: Kafka partitions / Redis Streams).

A ``PartitionedEventStore`` is N independent ``StreamShard`` commit logs per
workflow, with pluggable key→partition routing.  The default router is a
stable hash of the event *subject*, so a workflow's causally-related events
(everything addressed to the same trigger subject) stay totally ordered
within one partition — the same per-key ordering guarantee Kafka gives for
keyed topics.

Consumers address partitions explicitly (``consume_partitions`` /
``commit_partitions``): that is what lets a consumer group hand disjoint
partition subsets to worker shards and scale horizontally without breaking
the per-subject ordering or the at-least-once commit contract.

Locking is **striped per partition**: every ``StreamShard`` carries its own
lock and each operation takes only the locks of the partitions it touches,
so shard workers draining disjoint partition sets never serialize on the
store — they contend only on the interpreter itself.  (The pre-striping
behavior — one global RLock serializing all partitions — is kept behind
``striped=False`` as the contention baseline the benchmarks A/B against.)
Aggregate reads (``lag``, ``partition_lags`` …) visit shards one lock at a
time and are therefore momentary snapshots, exactly like Kafka consumer-lag
metrics; nothing in the worker/autoscaler contract needs a cross-partition
atomic view.
"""
from __future__ import annotations

import threading
import zlib
from typing import Callable, Dict, Iterable, List, Optional

from ..core.events import CloudEvent
from ..core.eventstore import EventStore, StreamShard

# subject -> partition. Stable across processes/restarts (crc32, not hash()).
Partitioner = Callable[[str, int], int]


def subject_partitioner(subject: str, num_partitions: int) -> int:
    return zlib.crc32(subject.encode("utf-8")) % num_partitions


class PartitionedEventStore(EventStore):
    """``EventStore`` contract per partition + partition-scoped consumer API.

    Per-partition guarantees (mirroring the single-stream ``StreamShard``):
    arrival order preserved, at-least-once redelivery of uncommitted events,
    commit offsets isolated per partition, per-partition DLQ + redrive.
    Cross-partition order is deliberately unspecified (as in Kafka).
    """

    #: ``consume`` never returns committed events, so an *exclusive* consumer
    #: (partition owner in a consumer group) may skip per-event is_committed
    #: checks and dedup only against its own in-flight set.
    UNCOMMITTED_ONLY = True

    def __init__(
        self,
        num_partitions: int = 8,
        partitioner: Optional[Partitioner] = None,
        striped: bool = True,
    ) -> None:
        if num_partitions < 1:
            raise ValueError("num_partitions must be >= 1")
        self.num_partitions = num_partitions
        self.partitioner: Partitioner = partitioner or subject_partitioner
        self.striped = striped
        # Guards only the workflow → shard-list map; every shard operation
        # synchronizes on the shard's own lock.
        self._lock = threading.Lock()
        self._parts: Dict[str, List[StreamShard]] = {}

    # -- routing ---------------------------------------------------------------
    def partition_for(self, subject: str) -> int:
        return self.partitioner(subject, self.num_partitions)

    def _shards(self, workflow: str) -> List[StreamShard]:
        parts = self._parts.get(workflow)
        if parts is None:
            with self._lock:
                parts = self._parts.get(workflow)
                if parts is None:
                    parts = [StreamShard() for _ in range(self.num_partitions)]
                    if not self.striped:
                        # coarse mode: all partitions share one lock — the
                        # pre-striping global-serialization baseline
                        shared = threading.Lock()
                        for s in parts:
                            s.lock = shared
                    self._parts[workflow] = parts
        return parts

    # -- EventStore contract (whole-stream view) -------------------------------
    def create_stream(self, workflow: str) -> None:
        self._shards(workflow)

    def publish(self, workflow: str, event: CloudEvent) -> None:
        shard = self._shards(workflow)[self.partition_for(event.subject)]
        with shard.lock:
            shard.publish((event,))

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        parts = self._shards(workflow)
        by_part: Dict[int, List[CloudEvent]] = {}
        for e in events:
            by_part.setdefault(self.partition_for(e.subject), []).append(e)
        # one append per touched partition, under that partition's lock only
        for p, evs in by_part.items():
            shard = parts[p]
            with shard.lock:
                shard.publish(evs)

    def _map_shards(self, workflow: str, fn) -> List:
        """Apply ``fn`` to every shard, each under its own lock (momentary
        per-partition snapshots — no cross-partition atomicity implied)."""
        parts = self._parts.get(workflow)
        if not parts:
            return []
        out = []
        for s in parts:
            with s.lock:
                out.append(fn(s))
        return out

    def _sum_partitions(self, workflow: str, partitions: Iterable[int],
                        fn) -> int:
        """Sum ``fn(shard)`` over the given partitions, striped-locked."""
        parts = self._parts.get(workflow)
        if not parts:
            return 0
        total = 0
        for p in partitions:
            shard = parts[p]
            with shard.lock:
                total += fn(shard)
        return total

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        return self.consume_partitions(
            workflow, range(self.num_partitions), max_events
        )

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        self.commit_partitions(workflow, range(self.num_partitions), event_ids)

    def is_committed(self, workflow: str, event_id: str) -> bool:
        parts = self._parts.get(workflow)
        if not parts:
            return False
        for s in parts:
            with s.lock:
                if s.is_committed(event_id):
                    return True
        return False

    def lag(self, workflow: str) -> int:
        return sum(self._map_shards(workflow, StreamShard.lag))

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        shard = self._shards(workflow)[self.partition_for(event.subject)]
        with shard.lock:
            shard.to_dlq(event)

    def redrive(self, workflow: str) -> int:
        return self.redrive_partitions(workflow, range(self.num_partitions))

    def dlq_size(self, workflow: str) -> int:
        return self.dlq_size_partitions(workflow, range(self.num_partitions))

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._parts.keys())

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        """Committed events, per-partition commit order, concatenated by
        partition index (cross-partition order is unspecified)."""
        out: List[CloudEvent] = []
        for chunk in self._map_shards(workflow, StreamShard.committed_events):
            out.extend(chunk)
        return out

    # -- partition-scoped consumer API (the consumer-group fast path) ----------
    def consume_partition(
        self, workflow: str, partition: int, max_events: int = 512
    ) -> List[CloudEvent]:
        parts = self._parts.get(workflow)
        if not parts:
            return []
        shard = parts[partition]
        with shard.lock:
            return shard.consume(max_events)

    def consume_partitions(
        self, workflow: str, partitions: Iterable[int], max_events: int = 512
    ) -> List[CloudEvent]:
        """Up to ``max_events`` uncommitted events from the given partitions,
        preserving arrival order *within* each partition."""
        parts = self._parts.get(workflow)
        if not parts:
            return []
        out: List[CloudEvent] = []
        budget = max_events
        for p in partitions:
            if budget <= 0:
                break
            shard = parts[p]
            with shard.lock:
                got = shard.consume(budget)
            out.extend(got)
            budget -= len(got)
        return out

    def commit_partitions(
        self, workflow: str, partitions: Iterable[int], event_ids: Iterable[str]
    ) -> int:
        ids = set(event_ids)
        if not ids:
            return 0
        parts = self._parts.get(workflow)
        if not parts:
            return 0
        # Per partition: intersect once (C-level), then the shard's bulk
        # commit handles its share — an O(batch) slice/set compare in the
        # common in-order case, degrading to prefix walk + scan only for
        # ids skipped mid-stream.
        n = 0
        want = len(ids)
        for p in partitions:
            shard = parts[p]
            with shard.lock:
                mine = ids & shard.pending_ids
                if mine:
                    n += shard.commit(mine)
            if n == want:
                break
        return n

    def partition_lags(self, workflow: str) -> List[int]:
        """Per-partition lag vector — the autoscaler's scaling signal."""
        return self._map_shards(workflow, StreamShard.lag) \
            or [0] * self.num_partitions

    def lag_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        return self._sum_partitions(workflow, partitions, StreamShard.lag)

    def commit_offsets(self, workflow: str) -> List[int]:
        """Per-partition committed-event counts (isolated commit offsets)."""
        return self._map_shards(workflow, StreamShard.commit_offset) \
            or [0] * self.num_partitions

    def dlq_size_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        return self._sum_partitions(workflow, partitions, StreamShard.dlq_size)

    def redrive_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        return self._sum_partitions(workflow, partitions, StreamShard.redrive)
