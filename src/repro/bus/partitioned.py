"""Partitioned event bus (paper §4: Kafka partitions / Redis Streams).

A partitioned store is N independent ``StreamShard`` commit logs per
workflow, with pluggable key→partition routing.  The default router is a
stable hash of the event *subject*, so a workflow's causally-related events
(everything addressed to the same trigger subject) stay totally ordered
within one partition — the same per-key ordering guarantee Kafka gives for
keyed topics.

Consumers address partitions explicitly (``consume_partitions`` /
``commit_partitions``): that is what lets a consumer group hand disjoint
partition subsets to worker shards and scale horizontally without breaking
the per-subject ordering or the at-least-once commit contract.

Two backends share the routing and consumer-API orchestration
(``PartitionedStoreBase``); they differ only in the per-partition
primitives:

* ``PartitionedEventStore`` — in-memory, the thread-shard fast path.
  Locking is **striped per partition**: every ``StreamShard`` carries its
  own lock and each operation takes only the locks of the partitions it
  touches, so shard workers draining disjoint partition sets never
  serialize on the store — they contend only on the interpreter itself.
  (The pre-striping behavior — one global RLock serializing all
  partitions — is kept behind ``striped=False`` as the contention baseline
  the benchmarks A/B against.)

* ``FilePartitionedEventStore`` — durable and **cross-process**: one
  append-only segment log (+ committed-offset log + DLQ ledger) per
  partition, file-locked per partition, with a ``StreamShard`` mirror per
  partition kept in sync by incremental replay.  This is what the
  multiprocess shard runtime (``repro.bus.proc``) runs on: the striped
  in-process locks become striped *file* locks, so independent partitions
  never contend across processes either.

Aggregate reads (``lag``, ``partition_lags`` …) visit partitions one lock
at a time and are therefore momentary snapshots, exactly like Kafka
consumer-lag metrics; nothing in the worker/autoscaler contract needs a
cross-partition atomic view.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterable, List, Optional

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: in-process locks only
    fcntl = None  # type: ignore[assignment]

from ..core import codec
from ..core.events import CloudEvent, stamp_publish_time
from ..core.eventstore import EventStore, SegmentLog, StreamShard, fsync_dir
from .replicate import ReplicationClient

# subject -> partition. Stable across processes/restarts (crc32, not hash()).
Partitioner = Callable[[str, int], int]


class FencedWrite(RuntimeError):
    """A stale partition owner tried to write past its lease.

    Raised (loudly) instead of appending: the partition's lease file carries
    a higher epoch (or a different owner) than the one this store instance
    acquired, which means ownership moved on — a paused/SIGSTOPped/netsplit
    node resuming must never silently interleave its writes with the new
    owner's.  The fence *latches*: once fenced, every further owner write to
    that partition is rejected until the runtime explicitly re-acquires the
    lease through a sanctioned assignment."""


def subject_partitioner(subject: str, num_partitions: int) -> int:
    return zlib.crc32(subject.encode("utf-8")) % num_partitions


class PartitionedStoreBase(EventStore):
    """Routing + the partition-scoped consumer API, over abstract
    per-partition primitives (``_*_p`` methods).

    Per-partition guarantees (mirroring the single-stream ``StreamShard``):
    arrival order preserved, at-least-once redelivery of uncommitted events,
    commit offsets isolated per partition, per-partition DLQ + redrive.
    Cross-partition order is deliberately unspecified (as in Kafka).
    """

    #: ``consume`` never returns committed events, so an *exclusive* consumer
    #: (partition owner in a consumer group) may skip per-event is_committed
    #: checks and dedup only against its own in-flight set.
    UNCOMMITTED_ONLY = True

    def __init__(self, num_partitions: int = 8,
                 partitioner: Optional[Partitioner] = None) -> None:
        if num_partitions < 1:
            raise ValueError("num_partitions must be >= 1")
        self.num_partitions = num_partitions
        self.partitioner: Partitioner = partitioner or subject_partitioner
        # Per-workflow partition-count overrides (``create_stream(wf, n)``).
        # ``num_partitions`` stays the store default; routing and every
        # whole-stream loop resolve the count per workflow, so a small
        # control workflow can ride the same bus as a wide data workflow
        # without inheriting its partition fan-out.
        self._np: Dict[str, int] = {}

    # -- routing ---------------------------------------------------------------
    def num_partitions_for(self, workflow: str) -> int:
        """The workflow's partition count (the autoscaler's shard cap)."""
        return self._np.get(workflow, self.num_partitions)

    def partition_for(self, subject: str, workflow: Optional[str] = None) -> int:
        n = self.num_partitions if workflow is None \
            else self.num_partitions_for(workflow)
        return self.partitioner(subject, n)

    # -- per-partition primitives (subclass responsibility) --------------------
    def _have(self, workflow: str) -> bool:
        raise NotImplementedError

    def _publish_p(self, workflow: str, p: int, events: List[CloudEvent]) -> None:
        raise NotImplementedError

    def _consume_p(self, workflow: str, p: int, max_events: int) -> List[CloudEvent]:
        raise NotImplementedError

    def _commit_p(self, workflow: str, p: int, ids: set) -> int:
        raise NotImplementedError

    def _lag_p(self, workflow: str, p: int) -> int:
        raise NotImplementedError

    def _dlq_size_p(self, workflow: str, p: int) -> int:
        raise NotImplementedError

    def _redrive_p(self, workflow: str, p: int, reasons=None) -> int:
        raise NotImplementedError

    def _dlq_by_reason_p(self, workflow: str, p: int) -> Dict[str, int]:
        raise NotImplementedError

    def _to_dlq_p(self, workflow: str, p: int, event: CloudEvent) -> None:
        raise NotImplementedError

    def _is_committed_p(self, workflow: str, p: int, event_id: str) -> bool:
        raise NotImplementedError

    def _commit_offset_p(self, workflow: str, p: int) -> int:
        raise NotImplementedError

    def _committed_events_p(self, workflow: str, p: int) -> List[CloudEvent]:
        raise NotImplementedError

    # -- EventStore contract (whole-stream view) -------------------------------
    def publish(self, workflow: str, event: CloudEvent) -> None:
        stamp_publish_time((event,))
        self._publish_p(
            workflow, self.partition_for(event.subject, workflow), [event])

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        events = list(events)
        stamp_publish_time(events)
        by_part: Dict[int, List[CloudEvent]] = {}
        for e in events:
            by_part.setdefault(
                self.partition_for(e.subject, workflow), []).append(e)
        # one append per touched partition, under that partition's lock only
        for p, evs in by_part.items():
            self._publish_p(workflow, p, evs)

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        return self.consume_partitions(
            workflow, range(self.num_partitions_for(workflow)), max_events)

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        self.commit_partitions(
            workflow, range(self.num_partitions_for(workflow)), event_ids)

    def is_committed(self, workflow: str, event_id: str) -> bool:
        if not self._have(workflow):
            return False
        return any(self._is_committed_p(workflow, p, event_id)
                   for p in range(self.num_partitions_for(workflow)))

    def lag(self, workflow: str) -> int:
        return self.lag_partitions(
            workflow, range(self.num_partitions_for(workflow)))

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        self._to_dlq_p(
            workflow, self.partition_for(event.subject, workflow), event)

    def redrive(self, workflow: str, reasons=None) -> int:
        return self.redrive_partitions(
            workflow, range(self.num_partitions_for(workflow)), reasons)

    def dlq_size(self, workflow: str) -> int:
        return self.dlq_size_partitions(
            workflow, range(self.num_partitions_for(workflow)))

    def dlq_by_reason(self, workflow: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for p in range(self.num_partitions_for(workflow)):
            for r, n in self._dlq_by_reason_p(workflow, p).items():
                out[r] = out.get(r, 0) + n
        return out

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        """Committed events, per-partition commit order, concatenated by
        partition index (cross-partition order is unspecified)."""
        out: List[CloudEvent] = []
        if not self._have(workflow):
            return out
        for p in range(self.num_partitions_for(workflow)):
            out.extend(self._committed_events_p(workflow, p))
        return out

    # -- partition-scoped consumer API (the consumer-group fast path) ----------
    def consume_partition(
        self, workflow: str, partition: int, max_events: int = 512
    ) -> List[CloudEvent]:
        if not self._have(workflow):
            return []
        return self._consume_p(workflow, partition, max_events)

    def consume_partitions(
        self, workflow: str, partitions: Iterable[int], max_events: int = 512
    ) -> List[CloudEvent]:
        """Up to ``max_events`` uncommitted events from the given partitions,
        preserving arrival order *within* each partition."""
        if not self._have(workflow):
            return []
        out: List[CloudEvent] = []
        budget = max_events
        for p in partitions:
            if budget <= 0:
                break
            got = self._consume_p(workflow, p, budget)
            out.extend(got)
            budget -= len(got)
        return out

    def commit_partitions(
        self, workflow: str, partitions: Iterable[int], event_ids: Iterable[str]
    ) -> int:
        ids = set(event_ids)
        if not ids or not self._have(workflow):
            return 0
        # Per partition: intersect once (C-level), then the shard's bulk
        # commit handles its share — an O(batch) slice/set compare in the
        # common in-order case, degrading to prefix walk + scan only for
        # ids skipped mid-stream.
        n = 0
        want = len(ids)
        for p in partitions:
            n += self._commit_p(workflow, p, ids)
            if n == want:
                break
        return n

    def partition_lags(self, workflow: str) -> List[int]:
        """Per-partition lag vector — the autoscaler's scaling signal."""
        n = self.num_partitions_for(workflow)
        if not self._have(workflow):
            return [0] * n
        return [self._lag_p(workflow, p) for p in range(n)]

    def lag_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        if not self._have(workflow):
            return 0
        return sum(self._lag_p(workflow, p) for p in partitions)

    def commit_offsets(self, workflow: str) -> List[int]:
        """Per-partition committed-event counts (isolated commit offsets)."""
        n = self.num_partitions_for(workflow)
        if not self._have(workflow):
            return [0] * n
        return [self._commit_offset_p(workflow, p) for p in range(n)]

    def dlq_size_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        if not self._have(workflow):
            return 0
        return sum(self._dlq_size_p(workflow, p) for p in partitions)

    def redrive_partitions(self, workflow: str, partitions: Iterable[int],
                           reasons=None) -> int:
        if not self._have(workflow):
            return 0
        return sum(self._redrive_p(workflow, p, reasons) for p in partitions)


class PartitionedEventStore(PartitionedStoreBase):
    """In-memory partitioned store: one ``StreamShard`` per partition,
    striped per-partition locking (``striped=False`` restores the old
    single-global-lock mode as the contention baseline)."""

    def __init__(
        self,
        num_partitions: int = 8,
        partitioner: Optional[Partitioner] = None,
        striped: bool = True,
    ) -> None:
        super().__init__(num_partitions, partitioner)
        self.striped = striped
        # Guards only the workflow → shard-list map; every shard operation
        # synchronizes on the shard's own lock.
        self._lock = threading.Lock()
        self._parts: Dict[str, List[StreamShard]] = {}

    def _shards(self, workflow: str) -> List[StreamShard]:
        parts = self._parts.get(workflow)
        if parts is None:
            with self._lock:
                parts = self._parts.get(workflow)
                if parts is None:
                    n = self.num_partitions_for(workflow)
                    parts = [StreamShard() for _ in range(n)]
                    if not self.striped:
                        # coarse mode: all partitions share one lock — the
                        # pre-striping global-serialization baseline
                        shared = threading.Lock()
                        for s in parts:
                            s.lock = shared
                    self._parts[workflow] = parts
        return parts

    def create_stream(self, workflow: str,
                      num_partitions: Optional[int] = None) -> None:
        if num_partitions is not None:
            if num_partitions < 1:
                raise ValueError("num_partitions must be >= 1")
            with self._lock:
                current = self._np.get(workflow)
                if workflow in self._parts and \
                        num_partitions != (current or self.num_partitions):
                    raise ValueError(
                        "stream %r exists with %s partitions, create_stream "
                        "asked for %s" % (workflow,
                                          current or self.num_partitions,
                                          num_partitions))
                self._np[workflow] = num_partitions
        self._shards(workflow)

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._parts.keys())

    # -- per-partition primitives ----------------------------------------------
    def _have(self, workflow: str) -> bool:
        return workflow in self._parts

    def _publish_p(self, workflow: str, p: int, events: List[CloudEvent]) -> None:
        shard = self._shards(workflow)[p]
        with shard.lock:
            shard.publish(events)

    def _consume_p(self, workflow: str, p: int, max_events: int) -> List[CloudEvent]:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.consume(max_events)

    def _commit_p(self, workflow: str, p: int, ids: set) -> int:
        shard = self._parts[workflow][p]
        with shard.lock:
            mine = ids & shard.pending_ids
            return shard.commit(mine) if mine else 0

    def _lag_p(self, workflow: str, p: int) -> int:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.lag()

    def _dlq_size_p(self, workflow: str, p: int) -> int:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.dlq_size()

    def _redrive_p(self, workflow: str, p: int, reasons=None) -> int:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.redrive(reasons)

    def _dlq_by_reason_p(self, workflow: str, p: int) -> Dict[str, int]:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.dlq_by_reason()

    def _to_dlq_p(self, workflow: str, p: int, event: CloudEvent) -> None:
        shard = self._shards(workflow)[p]
        with shard.lock:
            shard.to_dlq(event)

    def _is_committed_p(self, workflow: str, p: int, event_id: str) -> bool:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.is_committed(event_id)

    def _commit_offset_p(self, workflow: str, p: int) -> int:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.commit_offset()

    def _committed_events_p(self, workflow: str, p: int) -> List[CloudEvent]:
        shard = self._parts[workflow][p]
        with shard.lock:
            return shard.committed_events()


#: DLQ-ledger record marking "quarantined events went back into the stream"
#: (``redrive``).  A bare marker redrives everything; an optional ``reasons``
#: list restricts it to matching quarantine reasons (poison stays put).
#: Ordinary ledger records are CloudEvent dicts.
_REDRIVE_MARKER = {"__redrive__": 1}


def _encode_event_batch(seg: SegmentLog, events: List[CloudEvent]):
    """One log record per *publish batch*, in the segment's active format:
    a columnar TFB1 frame (``repro.core.codec`` — the 2x-cheaper decode) on
    a binary segment, a JSON array line on a v1 one.  Either way the
    per-record overhead amortizes across the batch and the torn-tail
    contract sits at the granularity writes actually happen (a torn batch
    was never acknowledged, so dropping it whole is exactly right)."""
    if seg.active_format() == "tfb1":
        return codec.encode_frame_payload(events)
    return json.dumps([e.to_dict() for e in events], separators=(",", ":"))


def _decode_event_batch(rec) -> List[CloudEvent]:
    """A scanned log record → events, payload-shape-blind: columnar
    frames, JSON arrays and single JSON event dicts all decode, whether
    the record arrived as bytes (tfb1) or a str line (v1).  Tolerance
    matters: a str record appended through ``SegmentLog.append`` on a
    binary segment arrives as JSON *bytes*, and hard-routing every bytes
    payload to the frame decoder would stall the scan at an acknowledged
    record forever (and the next locked writer would chop it)."""
    return codec.events_of(codec.decode_payload(rec))


#: Separator between a committed record's lease-epoch prefix and the event
#: id (``e<epoch>\x1f<id>``).  Unit separator: ids never contain it, and it
#: is a 1-byte ASCII control char so byte offsets stay equal to char counts.
_EPOCH_SEP = "\x1f"


def _encode_commit_line(event_id: str, epoch: Optional[int]) -> str:
    """A committed record; when the writer holds a lease it *carries the
    owner's epoch*, so any reader can audit that commit epochs only ever
    move forward (the fencing invariant, observable on disk)."""
    if epoch is None:
        return event_id
    return "e%d%s%s" % (epoch, _EPOCH_SEP, event_id)


def _decode_commit_line(line: str) -> str:
    """Committed record → event id (epoch prefix stripped if present)."""
    if line.startswith("e"):
        i = line.find(_EPOCH_SEP)
        if i > 1 and line[1:i].isdigit():
            return line[i + 1:]
    return line


def _commit_line_epoch(line: str) -> Optional[int]:
    """The epoch a committed record carries, if any (audit/tests)."""
    if line.startswith("e"):
        i = line.find(_EPOCH_SEP)
        if i > 1 and line[1:i].isdigit():
            return int(line[1:i])
    return None


class _FilePartition:
    """One partition's durable state + its in-process mirror.

    Files (all append-only ``SegmentLog``s, named ``p<k>.*``):

    * ``.log`` — the event segment log (publish order).
    * ``.committed`` — committed event ids, append order = commit order.
    * ``.dlq`` — quarantine ledger: event records interleaved with redrive
      markers; replaying it in order reconstructs the DLQ exactly.
    * ``.lock`` — the partition's cross-process lock file (``flock``): every
      *mutating* operation holds it exclusively, so the striped-locking
      design carries over across processes — writers to different partitions
      never contend.

    The ``StreamShard`` mirror gives consumers the same O(batch) commit/DLQ
    semantics as the in-memory bus; ``sync`` incrementally replays whatever
    the files gained since the last look (only whole, CRC-verified records
    in either wire format — a torn tail from a crashed writer is invisible
    until the next locked writer truncates it).  Readers sync lock-free;
    the mirror is private.
    """

    __slots__ = ("shard", "log", "com", "dlq", "lockf", "log_off", "com_off",
                 "dlq_off", "dlq_ids", "deferred", "last_full")

    #: How stale the committed/DLQ view of a *follower* mirror may get
    #: between full syncs.  Owners don't rely on it: every mutating op
    #: (commit / quarantine / redrive) full-syncs under the partition flock,
    #: and a partition's first sync after (re)assignment is always full.
    FULL_SYNC_INTERVAL = 0.05

    def __init__(self, base: str, fsync: bool, binary: bool = True) -> None:
        self.shard = StreamShard()
        # event + DLQ segments carry batch frames and prefer the binary
        # format for new files; the committed log stays line-oriented text —
        # its epoch-tagged id records are the on-disk fencing audit surface
        self.log = SegmentLog(base + ".log", fsync=fsync, binary=binary)
        self.com = SegmentLog(base + ".committed", fsync=fsync)
        self.dlq = SegmentLog(base + ".dlq", fsync=fsync, binary=binary)
        self.lockf = open(base + ".lock", "a")
        self.log_off = 0
        self.com_off = 0
        self.dlq_off = 0
        self.dlq_ids: set = set()
        # committed ids seen before their event's log line (the owner can
        # append log + committed between two of our scans): applied as soon
        # as the event appears.
        self.deferred: set = set()
        self.last_full = 0.0  # 0 ⇒ the very first sync is always full

    def sync(self, scan_log: bool = True, full: bool = False) -> None:
        """Replay new file records into the mirror (log → DLQ → committed:
        an id's lifecycle is publish → quarantine/redrive* → commit, so this
        order never applies an op before its subject exists; ops racing past
        the scan window land in ``deferred`` until their event shows up).

        Every file probe is a (sandbox-expensive) stat, so callers steer the
        scope: ``scan_log=False`` skips the event-log probe (the store's
        publish-notify counter already proved nothing was published), and the
        committed/DLQ ledgers are only re-probed every
        ``FULL_SYNC_INTERVAL`` seconds unless ``full`` forces it."""
        now = time.monotonic()
        if full or now - self.last_full >= self.FULL_SYNC_INTERVAL:
            full = True
            scan_log = True
            self.last_full = now
        shard = self.shard
        if scan_log:
            batches, self.log_off = self.log.scan(
                _decode_event_batch, self.log_off)
            if batches:
                pend, com, dlq = (shard.pending_ids, shard.committed_ids,
                                  self.dlq_ids)
                fresh = [e for batch in batches for e in batch
                         if e.id not in pend and e.id not in com
                         and e.id not in dlq]
                if fresh:
                    shard.publish(fresh)
        if not full:
            return
        ops, self.dlq_off = self.dlq.scan(codec.decode_payload, self.dlq_off)
        for op in ops:
            if isinstance(op, dict) and "__redrive__" in op:
                reasons = op.get("reasons")
                shard.redrive(reasons)
                self.dlq_ids = {e.id for e in shard.dlq}
            else:
                # v1: one event dict per record; tfb1: a columnar frame
                # (possibly several quarantined events per record)
                for ev in codec.events_of(op):
                    if ev.id in shard.committed_ids or ev.id in self.dlq_ids:
                        continue
                    self.dlq_ids.add(ev.id)
                    shard.to_dlq(ev)
        ids, self.com_off = self.com.scan(_decode_commit_line, self.com_off)
        if ids or self.deferred:
            want = self.deferred
            want.update(ids)
            mine = want & shard.pending_ids
            if mine:
                shard.commit(mine)
            self.deferred = want - shard.committed_ids


class FilePartitionedEventStore(PartitionedStoreBase):
    """Durable, cross-process partitioned store (the process-shard bus).

    Layout: ``<root>/<workflow>/p<k>.{log,committed,dlq,lock}`` (see
    ``_FilePartition``) plus ``<root>/bus.json`` pinning ``num_partitions``
    (subject routing must agree across every process that opens the root).

    Concurrency model: any process may *publish* to any partition (parent
    load injection, cross-partition ``ctx.produce``); consume/commit/DLQ of
    a partition come only from its consumer-group owner.  Every mutating
    operation syncs + appends under the partition's exclusive ``flock``;
    reads sync the private mirror lock-free and tolerate in-flight appends
    (whole-line scans).  A SIGKILLed writer's torn tail is truncated by the
    next locked writer before it appends (``flock`` dies with the process,
    and torn bytes are always the final bytes — every writer repairs before
    appending).

    ``fsync=False`` trades power-loss durability for throughput (the Kafka
    default-flush analogy: the OS page cache survives process SIGKILL, which
    is the failure mode the crash tests and the paper's Fig 13 exercise).
    """

    def __init__(
        self,
        root: str,
        num_partitions: int = 8,
        partitioner: Optional[Partitioner] = None,
        fsync: bool = True,
        replicate_to=None,
        replicate_sync: bool = False,
        replicate_prefix: str = "",
        lease_owner: Optional[str] = None,
        lease_ttl: float = 30.0,
        lease_skew_hook: Optional[Callable[[str, int], bool]] = None,
        replicate_fault_hook: Optional[Callable[[str, str], None]] = None,
        event_codec: str = "binary",
    ) -> None:
        super().__init__(num_partitions, partitioner)
        self.root = root
        self.fsync = fsync
        # event_codec picks the wire format for NEW event/DLQ segments:
        # "binary" (TFB1 columnar frames) or "json" (v1 array lines).  An
        # existing segment's sniffed format always wins, so mixed-version
        # processes sharing a root stay byte-compatible.
        self.event_codec = event_codec
        # -- host-loss fault domain -------------------------------------------
        # replicate_to: (host, port) of a ReplicaServer — every segment
        # mutation this process makes is shipped there (see repro.bus.replicate)
        self._rep: Optional[ReplicationClient] = None
        if replicate_to is not None:
            self._rep = ReplicationClient(
                replicate_to, root, sync=replicate_sync,
                fault_hook=replicate_fault_hook, prefix=replicate_prefix)
        # lease_owner: this process's fencing identity.  When set, owner-side
        # mutations (commit / quarantine / redrive) validate the partition's
        # lease epoch under the flock before appending; a superseded epoch
        # raises FencedWrite instead of interleaving.
        self.lease_owner = lease_owner
        self.lease_ttl = lease_ttl
        self.lease_skew_hook = lease_skew_hook  # chaos seam: force-expire
        self.fenced_writes = 0
        self._lease_epochs: Dict[Any, int] = {}  # (wf, p) -> acquired epoch
        self._fenced: set = set()                # latched (wf, p) fences
        os.makedirs(root, exist_ok=True)
        meta_p = os.path.join(root, "bus.json")
        if os.path.exists(meta_p):
            with open(meta_p) as f:
                meta = json.load(f)
            if meta.get("num_partitions") != num_partitions:
                raise ValueError(
                    "bus at %s has %s partitions, store opened with %s"
                    % (root, meta.get("num_partitions"), num_partitions))
        else:
            tmp = meta_p + ".%d.tmp" % os.getpid()
            with open(tmp, "w") as f:
                json.dump({"num_partitions": num_partitions}, f)
                f.flush()
                # the pin must be readable after a power cut, not just after
                # a process crash: os.replace publishes the *name* atomically
                # but not the bytes behind it
                os.fsync(f.fileno())
            os.replace(tmp, meta_p)
        self._lock = threading.Lock()  # guards the workflow → partitions map
        self._fps: Dict[str, List[_FilePartition]] = {}
        # publish-notify counter per workflow: one byte appended per publish
        # or redrive, so a consumer poll detects "nothing new anywhere" with
        # ONE stat instead of one per partition (syscalls are the hot cost).
        # Only *size change* carries meaning, so each writer periodically
        # resets the file to keep it O(1) on disk (readers compare != , not
        # >, so a shrink is just another change).
        self._notify_fd: Dict[str, Any] = {}
        self._notify_seen: Dict[str, int] = {}
        self._notify_bumps: Dict[str, int] = {}
        # last whole-stream lag computed by ``lag()``.  A drained (0) entry
        # lets an idle poll answer with ONE notify stat — lag can only grow
        # through publish/redrive, and both bump the notify counter.
        self._lag_cache: Dict[str, int] = {}
        self._lag_verified: Dict[str, float] = {}  # last full lag() sweep

    # -- plumbing ---------------------------------------------------------------
    def _wf_dir(self, workflow: str) -> str:
        return os.path.join(self.root, workflow.replace("/", "_"))

    def _notify_path(self, workflow: str) -> str:
        return os.path.join(self._wf_dir(workflow), "pub.notify")

    def _bump_notify(self, workflow: str) -> None:
        fd = self._notify_fd.get(workflow)
        if fd is None:
            fd = open(self._notify_path(workflow), "ab", buffering=0)
            self._notify_fd[workflow] = fd
        fd.write(b".")
        n = self._notify_bumps.get(workflow, 0) + 1
        self._notify_bumps[workflow] = n
        if n % 8192 == 0:
            # bound the counter file: a shrink is a size change too, so
            # racing readers/writers see it as an ordinary notification
            try:
                if os.path.getsize(self._notify_path(workflow)) > 65536:
                    os.truncate(self._notify_path(workflow), 0)
            except OSError:  # pragma: no cover
                pass

    def _notify_changed(self, workflow: str) -> bool:
        """One stat: did anyone publish/redrive since we last looked?"""
        try:
            size = os.path.getsize(self._notify_path(workflow))
        except OSError:
            size = 0
        if size != self._notify_seen.get(workflow):
            self._notify_seen[workflow] = size
            # whoever consumes the signal must re-probe; a cached drained
            # lag is stale the moment anything was published
            self._lag_cache.pop(workflow, None)
            return True
        return False

    def _parts(self, workflow: str) -> List[_FilePartition]:
        fps = self._fps.get(workflow)
        if fps is None:
            with self._lock:
                fps = self._fps.get(workflow)
                if fps is None:
                    n = self.num_partitions_for(workflow)
                    d = self._wf_dir(workflow)
                    os.makedirs(d, exist_ok=True)
                    fps = [
                        _FilePartition(os.path.join(d, "p%04d" % p),
                                       self.fsync,
                                       binary=self.event_codec == "binary")
                        for p in range(n)
                    ]
                    if self._rep is not None:
                        for fp in fps:
                            fp.log.replicator = self._rep
                            fp.com.replicator = self._rep
                            fp.dlq.replicator = self._rep
                    self._fps[workflow] = fps
        return fps

    def append_stats(self, workflow: Optional[str] = None) -> Dict[str, float]:
        """Durable-append accounting for the metrics plane: counts/seconds
        summed over every segment log (event/committed/DLQ) this process has
        open — the store's fsync time, as seen by the shard that paid it."""
        count = 0
        seconds = 0.0
        wfs = [workflow] if workflow is not None else list(self._fps.keys())
        for wf in wfs:
            for fp in self._fps.get(wf, ()):
                for seg in (fp.log, fp.com, fp.dlq):
                    count += seg.append_count
                    seconds += seg.append_seconds
        return {"appends": count, "append_seconds": seconds}

    def _stream_meta_path(self, workflow: str) -> str:
        return os.path.join(self._wf_dir(workflow), "stream.json")

    def num_partitions_for(self, workflow: str) -> int:
        """The workflow's pinned partition count.  ``stream.json`` (written by
        ``create_stream``) overrides the bus default, so every process that
        opens the root routes this workflow's subjects identically.  The
        answer is cached once known: create a stream (and its partition
        count) before other processes publish to it — the same ordering
        ``bus.json`` already requires for the bus default.  A workflow whose
        directory does not exist yet is NOT negative-cached: it may be
        mid-creation by another process, and poisoning the cache with the
        default would misroute its subjects forever once the pin lands."""
        n = self._np.get(workflow)
        if n is None:
            try:
                with open(self._stream_meta_path(workflow)) as f:
                    n = int(json.load(f)["num_partitions"])
            except (OSError, ValueError, KeyError, TypeError):
                n = self.num_partitions
                if not os.path.isdir(self._wf_dir(workflow)):
                    return n  # stream not created yet: don't cache the miss
            self._np[workflow] = n
        return n

    @contextmanager
    def _plock(self, fp: _FilePartition):
        """The partition's cross-process writer lock.  ``fp.shard.lock`` (the
        in-process striped lock) is always held around it, so one process
        never self-deadlocks on the flock."""
        if fcntl is None:  # pragma: no cover - non-POSIX
            yield
            return
        fcntl.flock(fp.lockf.fileno(), fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(fp.lockf.fileno(), fcntl.LOCK_UN)

    def _append_clean(self, seg: SegmentLog, off: int, lines) -> int:
        """Append under the flock: truncate a (dead writer's) torn tail past
        the synced offset first, so our records land on a line boundary."""
        seg.truncate(off)
        return off + seg.append(lines)

    def _append_batch_clean(
        self, seg: SegmentLog, off: int, events: List[CloudEvent]
    ) -> int:
        """Like ``_append_clean`` for one event batch, but the record is
        encoded AFTER the repair truncate: a truncate below the binary
        magic (a crash can leave a 1–4 byte header fragment, which sniffs
        as v1) frees the file to re-commit to the preferred format, so a
        format sniffed *before* the truncate can be stale — the append
        would then frame a v1 JSON line as a TFB1 record (or vice versa)
        and poison the scan at an acknowledged offset."""
        seg.truncate(off)
        return off + seg.append([_encode_event_batch(seg, events)])

    # -- lease-fenced ownership (the host-loss fault domain) -------------------
    # One JSON lease record per partition, next to ``stream.json``:
    # ``{"partition": p, "owner": <node id>, "epoch": n, "expires": unix-ts}``.
    # The *epoch* is a per-partition monotonic counter bumped on every
    # acquisition; the runtime (consumer-group assignment / host-loss
    # recovery) force-acquires on ownership change, and every owner-side
    # mutation re-validates its epoch atomically with the append (both under
    # the partition's exclusive flock) — so a stale owner is rejected, never
    # interleaved.  Expiry is the ownerless-cleanup signal, not the safety
    # mechanism: epochs do the fencing.

    def _lease_path(self, workflow: str, p: int) -> str:
        return os.path.join(self._wf_dir(workflow), "lease.p%04d.json" % p)

    def _read_lease(self, workflow: str, p: int) -> Dict[str, Any]:
        try:
            with open(self._lease_path(workflow, p)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {"partition": p, "owner": None, "epoch": 0, "expires": 0.0}

    def _write_lease(self, workflow: str, p: int, rec: Dict[str, Any]) -> None:
        path = self._lease_path(workflow, p)
        data = json.dumps(rec, separators=(",", ":"))
        tmp = path + ".%d.tmp" % os.getpid()
        with open(tmp, "w") as f:
            f.write(data)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
        if self._rep is not None:
            self._rep.ship_put(path, data)
            # ownership transitions are rare control-plane writes: push them
            # to the replica NOW, so a recovery sees the newest epochs and
            # its own bump stays strictly above any fenced zombie's
            if hasattr(self._rep, "flush"):
                self._rep.flush()

    def _acquire_lease_locked(self, workflow: str, p: int) -> int:
        cur = self._read_lease(workflow, p)
        epoch = int(cur.get("epoch", 0)) + 1
        self._write_lease(workflow, p, {
            "partition": p, "owner": self.lease_owner, "epoch": epoch,
            "expires": time.time() + self.lease_ttl})
        self._lease_epochs[(workflow, p)] = epoch
        self._fenced.discard((workflow, p))
        return epoch

    def acquire_partition_lease(self, workflow: str, p: int) -> int:
        """Force-acquire partition ``p``'s lease for this node (epoch bump).
        Called by the runtime on sanctioned ownership changes — consumer
        group assignment and host-loss recovery.  Returns the new epoch."""
        if self.lease_owner is None:
            raise ValueError("store has no lease_owner; cannot acquire")
        fp = self._parts(workflow)[p]
        with fp.shard.lock, self._plock(fp):
            return self._acquire_lease_locked(workflow, p)

    def reacquire_partition_leases(self, workflow: str,
                                   partitions: Iterable[int]) -> Dict[int, int]:
        """Acquire every given partition's lease; clears any fence latches.
        The runtime's assignment path (NOT individual writers) calls this —
        which is what lets a circuit breaker gate lease re-acquisition: no
        sanctioned assignment, no new epoch."""
        return {p: self.acquire_partition_lease(workflow, p)
                for p in partitions}

    def release_partition_lease(self, workflow: str, p: int) -> None:
        """Give the lease up cleanly (revoked partition): owner cleared,
        epoch preserved so the next acquisition still moves forward."""
        key = (workflow, p)
        epoch = self._lease_epochs.pop(key, None)
        self._fenced.discard(key)
        if epoch is None or self.lease_owner is None:
            return
        fp = self._parts(workflow)[p]
        with fp.shard.lock, self._plock(fp):
            cur = self._read_lease(workflow, p)
            if cur.get("owner") == self.lease_owner \
                    and cur.get("epoch") == epoch:
                self._write_lease(workflow, p, {
                    "partition": p, "owner": None, "epoch": epoch,
                    "expires": 0.0})

    def lease_holders(self, workflow: str) -> Dict[int, str]:
        """Current on-disk lease holder per partition (``owner@e<epoch>``),
        for diagnostics — what a stalled recovery shows in its timeout."""
        out: Dict[int, str] = {}
        for p in range(self.num_partitions_for(workflow)):
            rec = self._read_lease(workflow, p)
            if rec.get("owner") is not None:
                out[p] = "%s@e%s" % (rec["owner"], rec.get("epoch", 0))
        return out

    def _fence(self, workflow: str, p: int, why: str) -> None:
        self._fenced.add((workflow, p))
        self.fenced_writes += 1
        raise FencedWrite(
            "partition %d of %r: writes by %r fenced (%s)"
            % (p, workflow, self.lease_owner, why))

    def _check_lease(self, workflow: str, p: int) -> Optional[int]:
        """Validate (or first-acquire) this node's lease under the partition
        flock, immediately before an owner-side append.  Returns the epoch
        the append must carry, or None when leasing is off."""
        if self.lease_owner is None:
            return None
        key = (workflow, p)
        if key in self._fenced:
            self.fenced_writes += 1
            raise FencedWrite(
                "partition %d of %r: %r is fenced (lease superseded); "
                "writes stay rejected until re-assignment"
                % (p, workflow, self.lease_owner))
        epoch = self._lease_epochs.get(key)
        if epoch is None:
            return self._acquire_lease_locked(workflow, p)
        hook = self.lease_skew_hook
        if hook is not None and hook(workflow, p):
            self._fence(workflow, p,
                        "lease expired under injected clock skew")
        cur = self._read_lease(workflow, p)
        if cur.get("epoch") != epoch or cur.get("owner") != self.lease_owner:
            self._fence(workflow, p, "superseded by %s@e%s"
                        % (cur.get("owner"), cur.get("epoch")))
        if float(cur.get("expires", 0.0)) < time.time():
            # expired but unclaimed: renew in place (same epoch — only an
            # acquisition by another node moves the epoch)
            cur["expires"] = time.time() + self.lease_ttl
            self._write_lease(workflow, p, cur)
        return epoch

    def create_stream(self, workflow: str,
                      num_partitions: Optional[int] = None) -> None:
        if num_partitions is not None:
            if num_partitions < 1:
                raise ValueError("num_partitions must be >= 1")
            with self._lock:
                fps = self._fps.get(workflow)
                if fps is not None and len(fps) != num_partitions:
                    raise ValueError(
                        "stream %r already open with %d partitions, "
                        "create_stream asked for %s"
                        % (workflow, len(fps), num_partitions))
                d = self._wf_dir(workflow)
                if not os.path.isdir(d):
                    # the pin must be visible the instant the directory is:
                    # stage the dir WITH stream.json inside and rename it
                    # into place, so no observer (this process's autoscaler
                    # tick included) can ever see a pinned stream's dir
                    # without its pin and cache the bus default instead
                    tmp_d = d + ".%d.tmp" % os.getpid()
                    os.makedirs(tmp_d, exist_ok=True)
                    with open(os.path.join(tmp_d, "stream.json"), "w") as f:
                        json.dump({"num_partitions": num_partitions}, f)
                        f.flush()
                        # a power cut between the rename below and the disk
                        # writing the pin would leave the stream dir visible
                        # with an empty stream.json — every process would
                        # silently route by the bus default
                        # tfcheck: allow[lock-discipline] one-time stream creation; the pin must be durable before the rename publishes the dir
                        os.fsync(f.fileno())
                    try:
                        os.rename(tmp_d, d)
                        # the rename-into-place is the stream's creation
                        # event: fsync the parent so a crash right after
                        # cannot lose the directory entry (and the pin in it)
                        fsync_dir(self.root)
                    except OSError:  # lost the creation race: verify below
                        shutil.rmtree(tmp_d, ignore_errors=True)
                # re-read the effective pin from disk (ours, or a racing
                # creator's) and refuse a silent mismatch
                self._np.pop(workflow, None)
                pinned = self.num_partitions_for(workflow)
                if pinned != num_partitions:
                    raise ValueError(
                        "stream %r is pinned to %s partitions, create_stream "
                        "asked for %s" % (workflow, pinned, num_partitions))
                if self._rep is not None:
                    # the pin must survive host loss too: without it a
                    # restored root would fall back to the bus default and
                    # misroute every subject
                    self._rep.ship_put(
                        self._stream_meta_path(workflow),
                        json.dumps({"num_partitions": pinned}))
        self._parts(workflow)

    def workflows(self) -> List[str]:
        with self._lock:
            known = set(self._fps.keys())
        if os.path.isdir(self.root):
            known.update(
                d for d in os.listdir(self.root)
                if os.path.isdir(os.path.join(self.root, d)))
        return sorted(known)

    # -- per-partition primitives ----------------------------------------------
    def _have(self, workflow: str) -> bool:
        return workflow in self._fps or os.path.isdir(self._wf_dir(workflow))

    def _publish_p(self, workflow: str, p: int, events: List[CloudEvent]) -> None:
        fp = self._parts(workflow)[p]
        with fp.shard.lock, self._plock(fp):
            # scan_log before appending is mandatory: log_off must sit at the
            # true parseable EOF or _append_clean would chop foreign records
            fp.sync()
            fp.log_off = self._append_batch_clean(fp.log, fp.log_off, events)
            committed = fp.shard.committed_ids
            live = [e for e in events if e.id not in committed]
            if live:
                fp.shard.publish(live)
        self._bump_notify(workflow)

    def _consume_p(self, workflow: str, p: int, max_events: int) -> List[CloudEvent]:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync()
            return fp.shard.consume(max_events)

    def consume_partitions(
        self, workflow: str, partitions: Iterable[int], max_events: int = 512
    ) -> List[CloudEvent]:
        """The consumer hot path, syscall-gated: ONE stat on the workflow's
        publish-notify counter decides whether any partition log needs
        re-probing; otherwise events come straight from the mirrors (the
        periodic full sync inside ``_FilePartition.sync`` still bounds
        committed/DLQ staleness and backstops a publisher that died between
        its append and its notify bump)."""
        if not self._have(workflow):
            return []
        probe_logs = self._notify_changed(workflow)
        parts = self._parts(workflow)
        out: List[CloudEvent] = []
        budget = max_events
        for p in partitions:
            if budget <= 0:
                break
            fp = parts[p]
            with fp.shard.lock:
                fp.sync(scan_log=probe_logs or fp.last_full == 0.0)
                got = fp.shard.consume(budget)
            out.extend(got)
            budget -= len(got)
        return out

    def _commit_p(self, workflow: str, p: int, ids: set) -> int:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            # cheap miss, zero syscalls: committed ids were consumed from
            # this very mirror, so "none of them pending here" is exact
            if not ids & fp.shard.pending_ids:
                return 0
            with self._plock(fp):
                fp.sync(full=True)
                mine = ids & fp.shard.pending_ids
                if not mine:
                    return 0
                epoch = self._check_lease(workflow, p)
                fp.com_off = self._append_clean(
                    fp.com, fp.com_off,
                    [_encode_commit_line(i, epoch) for i in sorted(mine)])
                return fp.shard.commit(mine)

    def _lag_p(self, workflow: str, p: int) -> int:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync()
            return fp.shard.lag()

    def _probe_lag(self, fp: _FilePartition, probe: bool) -> int:
        """One partition's lag after a gated sync: the event log is only
        re-scanned when the notify counter said something was published (or
        on the partition's very first look); commits (which don't bump the
        counter) surface through the periodic full sync, so a drain-watcher
        polling lag converges within FULL_SYNC_INTERVAL."""
        with fp.shard.lock:
            fp.sync(scan_log=probe or fp.last_full == 0.0)
            return fp.shard.lag()

    def lag_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        """Like the consume path, syscall-gated: one notify stat decides
        whether any partition log needs probing (see ``_probe_lag``)."""
        if not self._have(workflow):
            return 0
        probe = self._notify_changed(workflow)
        parts = self._parts(workflow)
        return sum(self._probe_lag(parts[p], probe) for p in partitions)

    #: Even a cached-drained ``lag()`` re-sweeps at least this often: the
    #: append and its notify bump are not atomic across processes (a writer
    #: can die between them, and the counter's periodic truncation can alias
    #: a regrown size), so the cached 0 is only *almost* exact.  The backstop
    #: bounds how long such an orphan publish can hide; amortized, an idle
    #: tick still costs ~1 stat.
    LAG_BACKSTOP_INTERVAL = 1.0

    def lag(self, workflow: str) -> int:
        """Whole-stream lag, publish-notify-gated end to end: once a stream
        is observed drained, an idle poll answers with ONE stat on the notify
        counter — no per-partition syncs or ledger probes.  Lag only grows
        via publish/redrive, and both bump the counter *after* their flocked
        append, so an unchanged counter plus a cached 0 means drained — up
        to the non-atomicity of append+bump, which the periodic
        ``LAG_BACKSTOP_INTERVAL`` full sweep covers.  Any other state
        re-scans (commits by shard processes only ever shrink lag, and the
        scan keeps running until the drained 0 is observed and re-cached).
        This is what keeps an idle autoscaler tick O(1) instead of
        O(partitions)."""
        if not self._have(workflow):
            return 0
        probe = self._notify_changed(workflow)
        now = time.monotonic()
        if not probe and self._lag_cache.get(workflow) == 0 and \
                now - self._lag_verified.get(workflow, 0.0) < \
                self.LAG_BACKSTOP_INTERVAL:
            return 0
        total = sum(self._probe_lag(fp, probe)
                    for fp in self._parts(workflow))
        self._lag_cache[workflow] = total
        self._lag_verified[workflow] = now
        return total

    def partition_lags(self, workflow: str) -> List[int]:
        if not self._have(workflow):
            return [0] * self.num_partitions_for(workflow)
        probe = self._notify_changed(workflow)
        return [self._probe_lag(fp, probe) for fp in self._parts(workflow)]

    def _dlq_size_p(self, workflow: str, p: int) -> int:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync(scan_log=False)
            return fp.shard.dlq_size()

    def _redrive_p(self, workflow: str, p: int, reasons=None) -> int:
        fp = self._parts(workflow)[p]
        with fp.shard.lock, self._plock(fp):
            fp.sync(full=True)
            if not fp.shard.dlq_size():
                return 0
            epoch = self._check_lease(workflow, p)
            marker = dict(_REDRIVE_MARKER)
            if reasons is not None:
                marker["reasons"] = list(reasons)
            if epoch is not None:
                marker["epoch"] = epoch
            n = fp.shard.redrive(reasons)
            if not n:
                return 0
            # Ledger marker goes in regardless of how many matched on *this*
            # mirror — other mirrors replay the same selection against their
            # own state.
            fp.dlq_off = self._append_clean(
                fp.dlq, fp.dlq_off, [json.dumps(marker)])
            fp.dlq_ids = {e.id for e in fp.shard.dlq}
        self._bump_notify(workflow)
        return n

    def _dlq_by_reason_p(self, workflow: str, p: int) -> Dict[str, int]:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync(scan_log=False)
            return fp.shard.dlq_by_reason()

    def _to_dlq_p(self, workflow: str, p: int, event: CloudEvent) -> None:
        fp = self._parts(workflow)[p]
        with fp.shard.lock, self._plock(fp):
            fp.sync(full=True)
            self._check_lease(workflow, p)
            # truncate BEFORE sniffing the format (see _append_batch_clean):
            # a sub-magic repair truncate can flip the active format
            fp.dlq.truncate(fp.dlq_off)
            if fp.dlq.active_format() == "tfb1":
                rec = codec.encode_frame_payload([event])
            else:
                rec = event.to_json()  # legacy ledger shape: one event dict
            fp.dlq_off += fp.dlq.append([rec])
            fp.dlq_ids.add(event.id)
            fp.shard.to_dlq(event)

    def _is_committed_p(self, workflow: str, p: int, event_id: str) -> bool:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync(full=True)
            return fp.shard.is_committed(event_id)

    def _commit_offset_p(self, workflow: str, p: int) -> int:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync(full=True)
            return fp.shard.commit_offset()

    def _committed_events_p(self, workflow: str, p: int) -> List[CloudEvent]:
        fp = self._parts(workflow)[p]
        with fp.shard.lock:
            fp.sync(full=True)
            return fp.shard.committed_events()

    # -- replication surface + host-loss recovery ------------------------------
    def replica_lags(self, workflow: str) -> List[int]:
        """Per-partition unacked replication bytes (shipped by THIS process
        minus acked by the replica).  Zeros when replication is off."""
        n = self.num_partitions_for(workflow)
        out = [0] * n
        if self._rep is None:
            return out
        wfd = workflow.replace("/", "_")
        for rel, lag in self._rep.lag_by_rel().items():
            head, _, fn = rel.rpartition(os.sep)
            if os.path.basename(head) == wfd and fn.startswith("p") \
                    and fn[1:5].isdigit():
                p = int(fn[1:5])
                if p < n:
                    out[p] += lag
        return out

    def replication_stats(self) -> Dict[str, int]:
        if self._rep is None:
            return {"ships": 0, "errors": 0, "lag_bytes": 0}
        return {"ships": self._rep.ships, "errors": self._rep.errors,
                "lag_bytes": self._rep.replica_lag_bytes()}

    def drain_replication(self, timeout: float = 10.0) -> bool:
        """Wait for every shipped frame to be acked; True if drained."""
        if self._rep is None:
            return True
        return self._rep.drain(timeout)

    def heal_replication(self, workflow: str) -> None:
        """Force-reconcile the replica with the local files: ship a
        zero-length append at each segment's local EOF — a gap (e.g. from a
        dropped frame whose file was never appended to again) NACKs and
        heals from the local file."""
        if self._rep is None:
            return
        d = self._wf_dir(workflow)
        if not os.path.isdir(d):
            return
        for fn in sorted(os.listdir(d)):
            if fn.rpartition(".")[2] in ("log", "committed", "dlq"):
                path = os.path.join(d, fn)
                self._rep.ship_append(path, os.path.getsize(path), "")

    def restore_from_replica(self, workflow: str, replica_root: str) -> int:
        """Host-loss recovery: rebuild the workflow's segment root from a
        replica root (same layout, written by a ``ReplicaServer``).

        Copies the replica's files into place, then drops every in-memory
        mirror/cache so the next access replays the restored segments from
        offset zero through the ordinary torn-tail-tolerant ``sync`` path —
        recovery IS the crash-replay path, just fed from the replica's
        bytes.  Lease memory for the workflow is dropped too: ownership
        comes back only through explicit re-acquisition (epoch bump).
        Returns the number of bytes restored."""
        src = os.path.join(os.path.abspath(replica_root),
                           workflow.replace("/", "_"))
        dst = self._wf_dir(workflow)
        restored = 0
        with self._lock:
            fps = self._fps.pop(workflow, None)
            if fps:
                for fp in fps:
                    for seg in (fp.log, fp.com, fp.dlq):
                        seg.reset()
                    try:
                        fp.lockf.close()
                    except OSError:  # pragma: no cover
                        pass
            fd = self._notify_fd.pop(workflow, None)
            if fd is not None:
                try:
                    fd.close()
                except OSError:  # pragma: no cover
                    pass
            self._notify_seen.pop(workflow, None)
            self._lag_cache.pop(workflow, None)
            self._lag_verified.pop(workflow, None)
            for key in [k for k in self._lease_epochs if k[0] == workflow]:
                del self._lease_epochs[key]
            self._fenced = {k for k in self._fenced if k[0] != workflow}
            os.makedirs(dst, exist_ok=True)
            if os.path.isdir(src):
                for fn in sorted(os.listdir(src)):
                    if fn == "pub.notify":
                        continue
                    s = os.path.join(src, fn)
                    if not os.path.isfile(s):
                        continue
                    shutil.copyfile(s, os.path.join(dst, fn))
                    restored += os.path.getsize(s)
            fsync_dir(dst)
        # wake pollers: everything under the workflow changed
        self._bump_notify(workflow)
        return restored
