"""Partitioned event bus (paper §4: Kafka partitions / Redis Streams).

A ``PartitionedEventStore`` is N independent ``StreamShard`` commit logs per
workflow, with pluggable key→partition routing.  The default router is a
stable hash of the event *subject*, so a workflow's causally-related events
(everything addressed to the same trigger subject) stay totally ordered
within one partition — the same per-key ordering guarantee Kafka gives for
keyed topics.

Consumers address partitions explicitly (``consume_partitions`` /
``commit_partitions``): that is what lets a consumer group hand disjoint
partition subsets to worker shards and scale horizontally without breaking
the per-subject ordering or the at-least-once commit contract.
"""
from __future__ import annotations

import threading
import zlib
from typing import Callable, Dict, Iterable, List, Optional

from ..core.events import CloudEvent
from ..core.eventstore import EventStore, StreamShard

# subject -> partition. Stable across processes/restarts (crc32, not hash()).
Partitioner = Callable[[str, int], int]


def subject_partitioner(subject: str, num_partitions: int) -> int:
    return zlib.crc32(subject.encode("utf-8")) % num_partitions


class PartitionedEventStore(EventStore):
    """``EventStore`` contract per partition + partition-scoped consumer API.

    Per-partition guarantees (mirroring the single-stream ``StreamShard``):
    arrival order preserved, at-least-once redelivery of uncommitted events,
    commit offsets isolated per partition, per-partition DLQ + redrive.
    Cross-partition order is deliberately unspecified (as in Kafka).
    """

    #: ``consume`` never returns committed events, so an *exclusive* consumer
    #: (partition owner in a consumer group) may skip per-event is_committed
    #: checks and dedup only against its own in-flight set.
    UNCOMMITTED_ONLY = True

    def __init__(
        self,
        num_partitions: int = 8,
        partitioner: Optional[Partitioner] = None,
    ) -> None:
        if num_partitions < 1:
            raise ValueError("num_partitions must be >= 1")
        self.num_partitions = num_partitions
        self.partitioner: Partitioner = partitioner or subject_partitioner
        self._lock = threading.RLock()
        self._parts: Dict[str, List[StreamShard]] = {}

    # -- routing ---------------------------------------------------------------
    def partition_for(self, subject: str) -> int:
        return self.partitioner(subject, self.num_partitions)

    def _shards(self, workflow: str) -> List[StreamShard]:
        parts = self._parts.get(workflow)
        if parts is None:
            parts = self._parts.setdefault(
                workflow, [StreamShard() for _ in range(self.num_partitions)]
            )
        return parts

    # -- EventStore contract (whole-stream view) -------------------------------
    def create_stream(self, workflow: str) -> None:
        with self._lock:
            self._shards(workflow)

    def publish(self, workflow: str, event: CloudEvent) -> None:
        with self._lock:
            parts = self._shards(workflow)
            parts[self.partition_for(event.subject)].publish((event,))

    def publish_batch(self, workflow: str, events: Iterable[CloudEvent]) -> None:
        with self._lock:
            parts = self._shards(workflow)
            by_part: Dict[int, List[CloudEvent]] = {}
            for e in events:
                by_part.setdefault(self.partition_for(e.subject), []).append(e)
            for p, evs in by_part.items():
                parts[p].publish(evs)

    def consume(self, workflow: str, max_events: int = 512) -> List[CloudEvent]:
        return self.consume_partitions(
            workflow, range(self.num_partitions), max_events
        )

    def commit(self, workflow: str, event_ids: Iterable[str]) -> None:
        self.commit_partitions(workflow, range(self.num_partitions), event_ids)

    def is_committed(self, workflow: str, event_id: str) -> bool:
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return False
            return any(s.is_committed(event_id) for s in parts)

    def lag(self, workflow: str) -> int:
        with self._lock:
            parts = self._parts.get(workflow)
            return sum(s.lag() for s in parts) if parts else 0

    def to_dlq(self, workflow: str, event: CloudEvent) -> None:
        with self._lock:
            self._shards(workflow)[self.partition_for(event.subject)].to_dlq(event)

    def redrive(self, workflow: str) -> int:
        return self.redrive_partitions(workflow, range(self.num_partitions))

    def dlq_size(self, workflow: str) -> int:
        return self.dlq_size_partitions(workflow, range(self.num_partitions))

    def workflows(self) -> List[str]:
        with self._lock:
            return list(self._parts.keys())

    def committed_events(self, workflow: str) -> List[CloudEvent]:
        """Committed events, per-partition commit order, concatenated by
        partition index (cross-partition order is unspecified)."""
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return []
            out: List[CloudEvent] = []
            for s in parts:
                out.extend(s.committed_events())
            return out

    # -- partition-scoped consumer API (the consumer-group fast path) ----------
    def consume_partition(
        self, workflow: str, partition: int, max_events: int = 512
    ) -> List[CloudEvent]:
        with self._lock:
            parts = self._parts.get(workflow)
            return parts[partition].consume(max_events) if parts else []

    def consume_partitions(
        self, workflow: str, partitions: Iterable[int], max_events: int = 512
    ) -> List[CloudEvent]:
        """Up to ``max_events`` uncommitted events from the given partitions,
        preserving arrival order *within* each partition."""
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return []
            out: List[CloudEvent] = []
            budget = max_events
            for p in partitions:
                if budget <= 0:
                    break
                got = parts[p].consume(budget)
                out.extend(got)
                budget -= len(got)
            return out

    def commit_partitions(
        self, workflow: str, partitions: Iterable[int], event_ids: Iterable[str]
    ) -> int:
        ids = set(event_ids)
        if not ids:
            return 0
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return 0
            # Per partition: intersect once (C-level), then the shard's bulk
            # commit handles its share — an O(batch) slice/set compare in the
            # common in-order case, degrading to prefix walk + scan only for
            # ids skipped mid-stream.
            n = 0
            want = len(ids)
            for p in partitions:
                shard = parts[p]
                mine = ids & shard.pending_ids
                if mine:
                    n += shard.commit(mine)
                    if n == want:
                        break
            return n

    def partition_lags(self, workflow: str) -> List[int]:
        """Per-partition lag vector — the autoscaler's scaling signal."""
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return [0] * self.num_partitions
            return [s.lag() for s in parts]

    def lag_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        with self._lock:
            parts = self._parts.get(workflow)
            return sum(parts[p].lag() for p in partitions) if parts else 0

    def commit_offsets(self, workflow: str) -> List[int]:
        """Per-partition committed-event counts (isolated commit offsets)."""
        with self._lock:
            parts = self._parts.get(workflow)
            if not parts:
                return [0] * self.num_partitions
            return [s.commit_offset() for s in parts]

    def dlq_size_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        with self._lock:
            parts = self._parts.get(workflow)
            return sum(parts[p].dlq_size() for p in partitions) if parts else 0

    def redrive_partitions(self, workflow: str, partitions: Iterable[int]) -> int:
        with self._lock:
            parts = self._parts.get(workflow)
            return sum(parts[p].redrive() for p in partitions) if parts else 0
