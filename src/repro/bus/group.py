"""Consumer-group coordinator: partitions → worker shards.

Assignment is by *consistent hashing* (a ring of virtual nodes per member):
on join/leave/crash only the partitions owned by the affected member move,
so a rebalance does not reshuffle the whole group the way naive modulo
assignment would.  Every membership change bumps ``generation`` — the bus
pool uses that to know when shard assignments must be refreshed and
consumer-side state reset to the last checkpoint (Kafka's rebalance
semantics: a partition always restarts from its committed offset).
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from typing import Dict, List, Optional, Tuple


def _hash(key: str) -> int:
    return int.from_bytes(hashlib.md5(key.encode("utf-8")).digest()[:8], "big")


class ConsumerGroup:
    def __init__(self, num_partitions: int, virtual_nodes: int = 64) -> None:
        if num_partitions < 1:
            raise ValueError("num_partitions must be >= 1")
        self.num_partitions = num_partitions
        self.virtual_nodes = virtual_nodes
        self.generation = 0
        self._members: List[str] = []
        self._ring: List[Tuple[int, str]] = []
        self._ring_keys: List[int] = []
        self._part_hash = [_hash(f"partition:{p}") for p in range(num_partitions)]
        self._lock = threading.RLock()

    # -- membership ------------------------------------------------------------
    def join(self, member: str) -> List[int]:
        """Add a member; returns its partition assignment."""
        with self._lock:
            if member not in self._members:
                self._members.append(member)
                self._rebuild()
                self.generation += 1
            return self.partitions_of(member)

    def leave(self, member: str) -> None:
        """Remove a member (graceful leave or observed crash)."""
        with self._lock:
            if member in self._members:
                self._members.remove(member)
                self._rebuild()
                self.generation += 1

    def members(self) -> List[str]:
        with self._lock:
            return list(self._members)

    def _rebuild(self) -> None:
        ring = [
            (_hash(f"{m}#vn{i}"), m)
            for m in self._members
            for i in range(self.virtual_nodes)
        ]
        ring.sort()
        self._ring = ring
        self._ring_keys = [h for h, _ in ring]

    # -- assignment ------------------------------------------------------------
    def assignment(self) -> Dict[str, List[int]]:
        """member -> sorted partition list; covers every partition exactly once.

        Consistent hashing *with bounded loads*: each partition goes to the
        first ring member clockwise from its hash point whose load is under
        ``ceil(P / N)``.  The cap keeps shards balanced (a plain ring is very
        lopsided for small member counts) while membership changes still move
        only a bounded set of partitions.
        """
        with self._lock:
            out: Dict[str, List[int]] = {m: [] for m in self._members}
            ring = self._ring
            if not ring:
                return out
            cap = -(-self.num_partitions // len(self._members))  # ceil
            n_ring = len(ring)
            for p in range(self.num_partitions):
                i = bisect.bisect_right(self._ring_keys, self._part_hash[p])
                for k in range(n_ring):
                    m = ring[(i + k) % n_ring][1]
                    if len(out[m]) < cap:
                        out[m].append(p)
                        break
            return out

    def owner(self, partition: int) -> Optional[str]:
        for m, parts in self.assignment().items():
            if partition in parts:
                return m
        return None

    def partitions_of(self, member: str) -> List[int]:
        return self.assignment().get(member, [])
