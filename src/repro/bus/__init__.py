# Partitioned event bus + sharded worker-pool runtimes (paper §4 dataplane:
# Kafka partitions / Redis Streams consumer groups, scaled TF-Workers —
# threaded over the in-memory bus, or one OS process per shard over the
# durable file-backed bus), plus the host-loss fault domain (replicated
# segment transport + lease-fenced ownership).
from .group import ConsumerGroup
from .partitioned import (FencedWrite, FilePartitionedEventStore,
                          PartitionedEventStore, PartitionedStoreBase,
                          subject_partitioner)
from .pool import ShardedWorkerPool, ShardWorker
from .proc import ProcessShardPool
from .replicate import ReplicaServer, ReplicationClient

__all__ = [
    "ConsumerGroup",
    "FencedWrite",
    "FilePartitionedEventStore",
    "PartitionedEventStore",
    "PartitionedStoreBase",
    "ProcessShardPool",
    "ReplicaServer",
    "ReplicationClient",
    "ShardWorker",
    "ShardedWorkerPool",
    "subject_partitioner",
]
