# Partitioned event bus + sharded worker-pool runtimes (paper §4 dataplane:
# Kafka partitions / Redis Streams consumer groups, scaled TF-Workers —
# threaded over the in-memory bus, or one OS process per shard over the
# durable file-backed bus).
from .group import ConsumerGroup
from .partitioned import (FilePartitionedEventStore, PartitionedEventStore,
                          PartitionedStoreBase, subject_partitioner)
from .pool import ShardedWorkerPool, ShardWorker
from .proc import ProcessShardPool

__all__ = [
    "ConsumerGroup",
    "FilePartitionedEventStore",
    "PartitionedEventStore",
    "PartitionedStoreBase",
    "ProcessShardPool",
    "ShardWorker",
    "ShardedWorkerPool",
    "subject_partitioner",
]
