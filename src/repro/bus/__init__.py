# Partitioned event bus + sharded worker-pool runtime (paper §4 dataplane:
# Kafka partitions / Redis Streams consumer groups, scaled TF-Workers).
from .group import ConsumerGroup
from .partitioned import PartitionedEventStore, subject_partitioner
from .pool import ShardedWorkerPool, ShardWorker

__all__ = [
    "ConsumerGroup",
    "PartitionedEventStore",
    "ShardWorker",
    "ShardedWorkerPool",
    "subject_partitioner",
]
