"""Sharded TF-Worker pool over a partitioned event bus.

One workflow is served by N ``ShardWorker`` shards.  A ``ConsumerGroup``
assigns each shard a disjoint partition subset; shards consume, activate and
fire triggers exactly like the classic single ``TFWorker`` (they *are*
TFWorkers), but only over their own partitions.  Because the default router
keys partitions by event subject, a trigger's causally-related events land on
one shard and its context is never contended across shards.

Rebalance semantics (join/leave/crash) follow Kafka: a partition always
restarts from its committed offset, so on any assignment change a shard
resets its volatile state to the last checkpoint (``rebalance_reset``) and
uncommitted events are simply redelivered — the same at-least-once replay
path the paper uses for crash recovery (§3.4).

Sharding constraint: trigger *contexts* live with the shard that owns the
trigger's subject partition and are not synchronized across shards.
Cross-trigger introspection (Def. 5 — e.g. a Map action setting the
downstream join trigger's ``expected``) therefore requires the involved
subjects to share a partition; route them together with a custom
``partitioner`` on the ``PartitionedEventStore`` (e.g. hash on a workflow
stage prefix).  Cross-shard context routing is future work.
"""
from __future__ import annotations

import os
import threading
import time
import traceback
from typing import Any, Dict, List, Optional

from ..core.eventstore import EventStore
from ..core.functions import FunctionBackend
from ..core.policy import REASON_DISABLED, CircuitBreaker
from ..core.statestore import StateStore
from ..core.triggers import Trigger
from ..core.worker import TFWorker, WorkerStats
from ..obs.metrics import empty_snapshot, fold_counters, merge_snapshot
from .group import ConsumerGroup


class ShardWorker(TFWorker):
    """A TF-Worker that owns an exclusive partition subset of one workflow.

    The batch-plane loop (``TFWorker.run_once``) already gives shards their
    two fast-path specializations: exclusive partition ownership elides the
    per-event committed check (``UNCOMMITTED_ONLY``), and the compiled
    per-subject dispatch resolves registry lookups and trigger contexts once
    per slice.  What remains here is membership identity and the rebalance
    contract.
    """

    def __init__(self, member: str, *args, **kwargs) -> None:
        self.member = member
        super().__init__(*args, **kwargs)

    def rebalance_reset(self) -> None:
        """Reset volatile state to the last checkpoint.

        Called (with ``self.lock`` held by the pool) whenever this shard's
        partition assignment changes.  Processed-but-uncommitted events are
        still pending in the store and will be redelivered — replaying them
        over the checkpointed contexts is exactly the §3.4 crash-recovery
        contract, applied at rebalance points.
        """
        self._seen.clear()
        self._sink.clear()
        self._dlq_counted.clear()
        specs = self.state_store.get_triggers(self.workflow)
        ckpt = self.state_store.get_contexts(self.workflow)
        for tid, trg in self.triggers.items():
            base = specs.get(tid, {}).get("context", trg.context)
            trg.context = dict(ckpt.get(tid, base))
        self._contexts.clear()
        self._invalidate_dispatch()  # cached entries hold the old contexts


class _Runner(threading.Thread):
    """One runner thread multiplexing several shard *tasks* (Kafka-Streams
    style: task count — shards — is decoupled from thread count, so scaling
    shards past the core count doesn't buy GIL churn).

    A shard leaves its runner when it is stopped, finishes its workflow,
    idles past ``idle_timeout`` (KEDA-style scale-down), or its batch raises;
    the departure *reason* is recorded on the worker (``exit_reason``) and
    ``on_exit`` fires so the pool can react immediately — in particular a
    batch that raised must surrender its partitions right away, not wait for
    someone to call ``reap()``.  The runner exits once it owns no shards."""

    def __init__(self, name: str, idle_timeout: Optional[float], poll: float,
                 on_exit=None) -> None:
        super().__init__(name=name, daemon=True)
        self.workers: Dict[str, ShardWorker] = {}
        self.idle_timeout = idle_timeout
        self.poll = poll
        self.on_exit = on_exit
        self.closing = False
        self._close_lock = threading.Lock()

    def add(self, member: str, worker: ShardWorker) -> bool:
        """Hand a shard task to this runner.  Returns False if the runner is
        on its way out (its loop saw an empty task set) — the caller must pick
        another runner, or the shard would never be scheduled."""
        with self._close_lock:
            if self.closing:
                return False
            worker.last_active = time.monotonic()
            worker.exit_reason = None
            self.workers[member] = worker
            return True

    def _drop(self, member: str, w: ShardWorker, reason: str) -> None:
        w.exit_reason = reason
        self.workers.pop(member, None)
        if self.on_exit is not None:
            try:
                self.on_exit(member, w)
            except Exception:  # noqa: BLE001 - pool reaction must not kill the runner
                traceback.print_exc()

    def run(self) -> None:
        while True:
            n = 0
            for member, w in list(self.workers.items()):
                if w._stop.is_set() or w.finished:
                    self._drop(member, w,
                               "finished" if w.finished else "stopped")
                    continue
                try:
                    n += w.run_once()
                except Exception:  # noqa: BLE001 - a broken shard must not kill siblings
                    traceback.print_exc()
                    self._drop(member, w, "error")
                    continue
                if self.idle_timeout is not None and \
                        time.monotonic() - w.last_active > self.idle_timeout:
                    self._drop(member, w, "idle")
            if not self.workers:
                with self._close_lock:
                    if not self.workers:  # nothing raced in: commit to exit
                        self.closing = True
                        return
            elif n == 0:
                time.sleep(self.poll)


class _WorkflowShards:
    __slots__ = ("group", "shards", "runner_of", "next_id",
                 "failures", "failed_unreaped", "rebalances", "retired",
                 "breaker")

    def __init__(self, num_partitions: int,
                 breaker: Optional[CircuitBreaker] = None) -> None:
        self.group = ConsumerGroup(num_partitions)
        self.shards: Dict[str, ShardWorker] = {}
        self.runner_of: Dict[str, _Runner] = {}
        self.next_id = 0
        self.failures = 0        # shards whose batch raised (lifetime total)
        self.failed_unreaped = 0  # …not yet folded into a reap() report
        self.rebalances = 0      # partition-assignment changes (lifetime)
        # lifetime stats of departed shards, folded via WorkerStats so they
        # aggregate identically to the process pool's retired_stats
        self.retired = WorkerStats()
        # crash-loop breaker: consecutive-crash streak gates start_shards
        self.breaker = breaker if breaker is not None else CircuitBreaker()


class ShardedWorkerPool:
    """Runs N TF-Worker shards per workflow over a ``PartitionedEventStore``."""

    def __init__(
        self,
        event_store: EventStore,
        state_store: StateStore,
        backend: FunctionBackend,
        timers=None,
        commit_policy: str = "on_fire",
        batch_size: int = 512,
        keep_event_log: bool = True,
        batch_plane: bool = True,
        action_plane: bool = True,
        metrics: bool = True,
        tracer=None,
        breaker: Optional[Dict[str, Any]] = None,
    ) -> None:
        if not hasattr(event_store, "consume_partitions"):
            raise TypeError(
                "ShardedWorkerPool needs a partitioned event store "
                "(missing consume_partitions); got %r" % type(event_store).__name__)
        self.event_store = event_store
        self.state_store = state_store
        self.backend = backend
        self.timers = timers
        self.commit_policy = commit_policy
        self.batch_size = batch_size
        self.keep_event_log = keep_event_log
        self.batch_plane = batch_plane
        self.action_plane = action_plane
        # Observability (repro.obs): per-shard metric registries, merged on
        # scrape (obs_snapshot); one shared tracer (its collector's ring
        # buffer is append-atomic, so shard threads share it lock-free).
        self.metrics_enabled = metrics
        self.tracer = tracer
        # CircuitBreaker kwargs applied to every workflow's crash-loop
        # breaker (threshold / backoff_* / cooldown — see core.policy).
        self.breaker_conf = dict(breaker) if breaker else {}
        self._lock = threading.RLock()
        self._wfs: Dict[str, _WorkflowShards] = {}

    # -- membership ------------------------------------------------------------
    def _np_for(self, workflow: str) -> int:
        npf = getattr(self.event_store, "num_partitions_for", None)
        return npf(workflow) if npf is not None \
            else self.event_store.num_partitions

    def _wf(self, workflow: str) -> _WorkflowShards:
        wp = self._wfs.get(workflow)
        n = self._np_for(workflow)
        if wp is None:
            wp = self._wfs.setdefault(
                workflow,
                _WorkflowShards(n, CircuitBreaker(**self.breaker_conf)))
        elif wp.group.num_partitions != n:
            # a per-workflow partition pin landed after this group was sized
            # (e.g. the workflow was touched before create_stream pinned it):
            # resize while empty; with live members the widths have diverged
            # for good and silently continuing would strand partitions
            if wp.group.members():
                raise ValueError(
                    "workflow %r is sharded over %d partitions but the store "
                    "now pins %d" % (workflow, wp.group.num_partitions, n))
            wp.group = ConsumerGroup(n)
        return wp

    # -- ScalablePool surface (see repro.core.autoscaler) -----------------------
    def lag(self, workflow: str) -> int:
        return self.event_store.lag(workflow)

    def num_partitions(self, workflow: str) -> int:
        """The workflow's partition count — the hard shard cap (a shard
        without a partition has nothing to consume)."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is not None:
                return wp.group.num_partitions
        return self._np_for(workflow)

    def breaker_of(self, workflow: str) -> CircuitBreaker:
        """The workflow's crash-loop breaker (autoscaler gate + tests)."""
        with self._lock:
            return self._wf(workflow).breaker

    def local_worker(self, workflow: str) -> Optional[ShardWorker]:
        """First in-process shard worker, if any (the service facade's
        classic-API bridge; process pools have no in-process workers)."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None or not wp.shards:
                return None
            return next(iter(wp.shards.values()))

    def shard_ids(self, workflow: str) -> List[str]:
        with self._lock:
            wp = self._wfs.get(workflow)
            return list(wp.shards.keys()) if wp else []

    def shard_count(self, workflow: str) -> int:
        with self._lock:
            wp = self._wfs.get(workflow)
            return len(wp.shards) if wp else 0

    def live_shard_count(self, workflow: str) -> int:
        """Shards currently owned by a live runner thread (threaded mode)."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return 0
            return sum(
                1 for m, r in wp.runner_of.items()
                if r.is_alive() and m in r.workers
            )

    def add_shard(self, workflow: str) -> str:
        with self._lock:
            wp = self._wf(workflow)
            member = f"shard-{wp.next_id}"
            wp.next_id += 1
            worker = ShardWorker(
                member,
                workflow,
                self.event_store,
                self.state_store,
                self.backend,
                batch_size=self.batch_size,
                commit_policy=self.commit_policy,
                keep_event_log=self.keep_event_log,
                timers=self.timers,
                partitions=(),
                batch_plane=self.batch_plane,
                action_plane=self.action_plane,
                metrics=self.metrics_enabled,
                tracer=self.tracer,
            )
            wp.shards[member] = worker
            wp.group.join(member)
            self._rebalance(wp)
            return member

    def _retire(self, wp: _WorkflowShards, member: str) -> None:
        """Drop ``member`` and hand its partitions to the rest.  The victim's
        lock is taken once before rebalancing: an in-flight batch on a runner
        thread finishes (and commits/checkpoints) first, so a 'zombie' shard
        can never fire or commit concurrently with the new partition owner."""
        worker = wp.shards.pop(member)
        worker._stop.set()
        runner = wp.runner_of.pop(member, None)
        if runner is not None:
            runner.workers.pop(member, None)
        with worker.lock:  # fence: wait out any in-flight batch
            pass
        wp.group.leave(member)
        # a graceful leave keeps its lifetime counters (WorkerStats.merge —
        # the same fold the process pool applies to a clean child's exit
        # stats, so the two runtimes' lifetime totals mean the same thing)
        wp.retired.merge(worker.stats)
        wp.breaker.record_clean()
        self._rebalance(wp)

    def remove_shard(self, workflow: str, member: str) -> None:
        """Graceful leave: stop the shard, hand its partitions to the rest."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is not None and member in wp.shards:
                self._retire(wp, member)

    def crash_shard(self, workflow: str, member: str) -> None:
        """Simulate a shard crash: drop it with NO further checkpoint/commit.

        Unlike ``remove_shard`` (which fences and lets an in-flight batch
        finish, commit and checkpoint — a *graceful* leave), the victim is
        ``kill()``-ed first: an in-flight batch completes its in-memory work
        but **discards** its checkpoint/commit, so everything it consumed
        stays pending in the store and is redelivered to the shards the group
        reassigns those partitions to — redelivery happens *at the crash
        point*, not at the next batch boundary.  (In-process a thread cannot
        be preempted mid-batch; the real mid-batch SIGKILL lives in
        ``repro.bus.proc.ProcessShardPool``.)"""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None or member not in wp.shards:
                return
            worker = wp.shards.pop(member)
            worker.kill()  # in-flight batch now discards its commit
            runner = wp.runner_of.pop(member, None)
            if runner is not None:
                runner.workers.pop(member, None)
            with worker.lock:  # fence: wait out the (discarding) batch
                pass
            wp.group.leave(member)
            wp.breaker.record_crash()
            self._rebalance(wp)

    def _shard_exited(self, workflow: str, member: str, worker) -> None:
        """Runner callback: a shard left its runner.  Only a *failed* batch
        needs immediate action — the dead shard still owns its partitions and
        with no autoscaler loop calling ``reap()`` they would stall silently
        forever.  Surface the failure (stat + log) and rebalance now."""
        if worker.exit_reason != "error":
            return  # stopped / finished / idle: reap() accounts for these
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None or wp.shards.get(member) is not worker:
                return  # already retired (reap/remove raced us)
            wp.shards.pop(member, None)
            wp.runner_of.pop(member, None)
            wp.failures += 1
            wp.failed_unreaped += 1
            wp.group.leave(member)
            wp.breaker.record_crash()
            self._rebalance(wp)
        print("[pool] shard %s of workflow %r failed its batch; "
              "partitions rebalanced to %d remaining shard(s)"
              % (member, workflow, self.shard_count(workflow)))

    def _rebalance(self, wp: _WorkflowShards) -> None:
        wp.rebalances += 1
        assignment = wp.group.assignment()
        granted: set = set()
        for member, worker in wp.shards.items():
            parts = tuple(assignment.get(member, ()))
            with worker.lock:
                if worker.partitions != parts:
                    worker.partitions = parts
                    worker.rebalance_reset()
            granted.update(parts)
        # lease-fenced stores (host-loss fault domain): a rebalance is the
        # only sanctioned ownership change, so it is the only place fence
        # latches clear.  With the breaker open no shards start, no
        # rebalance grants anything, and no lease is re-acquired — the
        # fencing plane honors the failure-policy plane's quarantine.
        reacquire = getattr(self.event_store, "reacquire_partition_leases",
                            None)
        if reacquire is not None and granted \
                and getattr(self.event_store, "lease_owner", None) is not None:
            for wf, w in self._wfs.items():
                if w is wp:
                    reacquire(wf, sorted(granted))
                    break

    def set_shard_count(self, workflow: str, count: int) -> List[str]:
        """Add/remove (drive-mode) shards to reach ``count``; returns ids."""
        with self._lock:
            while self.shard_count(workflow) < count:
                self.add_shard(workflow)
            wp = self._wfs.get(workflow)
            while wp is not None and len(wp.shards) > count:
                self.remove_shard(workflow, next(reversed(wp.shards)))
            return self.shard_ids(workflow)

    # -- threaded mode (autoscaler / benchmarks) --------------------------------
    def start_shards(
        self,
        workflow: str,
        count: int,
        idle_timeout: Optional[float] = None,
        poll: float = 0.002,
        max_threads: Optional[int] = None,
    ) -> List[str]:
        """Ensure ``count`` shard tasks exist and are scheduled on runner
        threads.  At most ``max_threads`` (default: core count) runners serve
        a workflow — shards are *tasks*, threads are execution slots."""
        with self._lock:
            wp = self._wf(workflow)
            need = count - len(wp.shards)
            if need > 0:
                # crash-loop breaker: a streak of shard crashes makes fresh
                # starts wait out an exponential backoff; past the threshold
                # the circuit opens (no starts) until a cooldown admits one
                # half-open probe.  Existing (stopped) shards reschedule
                # freely — only NEW capacity is gated.
                granted = wp.breaker.allow_start(need)
                if granted < need:
                    print("[pool] circuit breaker for workflow %r (%s, "
                          "streak=%d): granting %d/%d shard start(s)"
                          % (workflow, wp.breaker.state, wp.breaker.streak,
                             granted, need))
                for _ in range(granted):
                    self.add_shard(workflow)
            cap = max(1, max_threads or os.cpu_count() or 2)
            unassigned = []
            for member, worker in wp.shards.items():
                runner = wp.runner_of.get(member)
                if runner is not None and runner.is_alive() \
                        and not runner.closing and member in runner.workers:
                    continue
                worker._stop.clear()
                unassigned.append(member)
            if unassigned:
                on_exit = (lambda m, w, _wf=workflow:
                           self._shard_exited(_wf, m, w))
                slots = [r for r in set(wp.runner_of.values())
                         if r.is_alive() and not r.closing]
                fresh = [
                    _Runner(f"tf-{workflow}-runner-{wp.next_id}-{i}",
                            idle_timeout, poll, on_exit)
                    for i in range(min(cap - len(slots), len(unassigned)))
                ]
                slots += fresh
                if not slots:
                    fresh = [_Runner(f"tf-{workflow}-runner-{wp.next_id}-x",
                                     idle_timeout, poll, on_exit)]
                    slots = list(fresh)
                for i, member in enumerate(unassigned):
                    runner = slots[i % len(slots)]
                    if not runner.add(member, wp.shards[member]):
                        # runner committed to exit between the liveness check
                        # and the add — replace the slot with a fresh runner
                        runner = _Runner(
                            f"tf-{workflow}-runner-{wp.next_id}-r{i}",
                            idle_timeout, poll, on_exit)
                        fresh.append(runner)
                        slots[i % len(slots)] = runner
                        runner.add(member, wp.shards[member])
                    wp.runner_of[member] = runner
                for r in fresh:
                    r.start()
            return list(wp.shards.keys())

    def reap(self, workflow: str) -> Dict[str, Any]:
        """Remove shards that left their runner (idle scale-down, workflow
        end, crash, or runner death).  Returns
        ``{"reaped": n, "crashed": m, "reasons": {reason: count}}`` for the
        autoscaler's accounting (the ``ScalablePool`` contract).

        "Crashed" is decided by the *recorded departure reason*
        (``TFWorker.crashed``), not by circumstantial evidence: an
        idle-timeout departure is a clean scale-down even if new events
        arrived after the shard went idle (``stopped`` unset + lag > 0 is not
        a crash), while a failed batch or a runner thread that died without
        recording any reason is."""
        reaped = crashed = 0
        reasons: Dict[str, int] = {}
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return {"reaped": 0, "crashed": 0, "reasons": {}}
            # failed-batch exits were retired immediately by _shard_exited;
            # fold them into this report exactly once
            if wp.failed_unreaped:
                reaped += wp.failed_unreaped
                crashed += wp.failed_unreaped
                reasons["error"] = wp.failed_unreaped
                wp.failed_unreaped = 0
            for member, runner in list(wp.runner_of.items()):
                if runner.is_alive() and member in runner.workers:
                    continue
                wp.runner_of.pop(member, None)
                worker = wp.shards.pop(member, None)
                wp.group.leave(member)
                reaped += 1
                reason = "lost" if worker is None else (
                    worker.exit_reason
                    or ("finished" if worker.finished else "lost"))
                reasons[reason] = reasons.get(reason, 0) + 1
                if worker is not None and worker.crashed:
                    crashed += 1
                    wp.breaker.record_crash()
                elif worker is not None:
                    wp.breaker.record_clean()
                    # clean departures keep their lifetime counters; a crash
                    # does not (its uncommitted work is replayed and counted
                    # again by the next owner — same as a SIGKILLed process
                    # shard, whose counters die with it)
                    wp.retired.merge(worker.stats)
            if reaped:
                self._rebalance(wp)
        return {"reaped": reaped, "crashed": crashed, "reasons": reasons}

    def stop(self, workflow: str) -> None:
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return
            for worker in wp.shards.values():
                worker.stop()
            runners = list(set(wp.runner_of.values()))
        for r in runners:
            r.join(timeout=2.0)

    def stop_all(self) -> None:
        for wf in list(self._wfs.keys()):
            self.stop(wf)

    # -- deterministic drive mode (tests, benchmarks) ---------------------------
    def run_shard_once(
        self, workflow: str, member: str, max_events: Optional[int] = None
    ) -> int:
        with self._lock:
            worker = self._wf(workflow).shards[member]
        return worker.run_once(max_events)

    def drive(self, workflow: str, timeout: float = 30.0, poll: float = 0.0005) -> Any:
        """Round-robin every shard until the stream drains (or the workflow
        sets a result).  Single-threaded and deterministic."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                shards = list(self._wf(workflow).shards.values())
            n = 0
            for worker in shards:
                if worker.finished:
                    return worker.result
                n += worker.run_once()
            if n == 0:
                if self.event_store.lag(workflow) == 0:
                    return None
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"workflow {workflow} did not drain: "
                        + self.failure_diagnostics(workflow))
                time.sleep(poll)

    def failure_diagnostics(self, workflow: str) -> str:
        """One-line triage string for drain timeouts: per-partition lag, DLQ
        breakdown by reason, live shard count and breaker state."""
        try:
            lag_vec = self.event_store.partition_lags(workflow)
        except Exception:  # noqa: BLE001 - diagnostics must never raise
            lag_vec = []
        lags = lag_vec if isinstance(lag_vec, dict) else dict(enumerate(lag_vec))
        dbr = getattr(self.event_store, "dlq_by_reason", None)
        try:
            dlq = dbr(workflow) if dbr is not None else {}
        except Exception:  # noqa: BLE001
            dlq = {}
        with self._lock:
            wp = self._wfs.get(workflow)
            breaker = wp.breaker.snapshot() if wp else {}
        rl = getattr(self.event_store, "replica_lags", None)
        try:
            rep_lag = {p: n for p, n in enumerate(rl(workflow)) if n} \
                if rl is not None else {}
        except Exception:  # noqa: BLE001
            rep_lag = {}
        lh = getattr(self.event_store, "lease_holders", None)
        try:
            leases = lh(workflow) if lh is not None else {}
        except Exception:  # noqa: BLE001
            leases = {}
        return (f"lag={sum(lags.values())} "
                f"partition_lags={ {p: n for p, n in lags.items() if n} } "
                f"dlq_by_reason={dlq} "
                f"live_shards={self.live_shard_count(workflow)} "
                f"breaker={breaker} "
                f"replica_lag={rep_lag} "
                f"leases={leases}")

    # -- trigger management (broadcast to every shard) --------------------------
    def add_trigger(self, workflow: str, trigger: Trigger) -> str:
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None or not wp.shards:
                self.state_store.put_trigger(
                    workflow, trigger.trigger_id, trigger.to_dict())
                return trigger.trigger_id
            first = True
            for worker in wp.shards.values():
                worker.add_trigger(trigger, persist=first)
                first = False
            return trigger.trigger_id

    def set_trigger_enabled(self, workflow: str, trigger_id: str, enabled: bool) -> None:
        """Broadcast the enable/disable to every shard.  Re-enabling also
        redrives the DLQ of the trigger's subject partitions (§3.4: events
        quarantined while the trigger was disabled become deliverable the
        moment its state changes)."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return
            subjects: List[str] = []
            for worker in wp.shards.values():
                trg = worker.triggers.get(trigger_id)
                if trg is not None:
                    worker.set_trigger_enabled(trigger_id, enabled)
                    subjects = trg.activation_events
            if enabled and subjects:
                parts = {self.event_store.partition_for(s, workflow)
                         for s in subjects}
                # only ``disabled`` quarantines become deliverable again;
                # poison:* stays put until an operator redrives explicitly
                self.event_store.redrive_partitions(
                    workflow, parts, reasons=(REASON_DISABLED,))

    def trigger_context(self, workflow: str, trigger_id: str) -> Dict[str, Any]:
        """Context as seen by the shard that owns the trigger's subject."""
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return {}
            for worker in wp.shards.values():
                trg = worker.triggers.get(trigger_id)
                if trg is None or not trg.activation_events:
                    continue
                p = self.event_store.partition_for(
                    trg.activation_events[0], workflow)
                if worker.partitions and p in worker.partitions:
                    return dict(worker.context_of(trigger_id))
            return {}

    # -- metrics (the autoscaler's and benchmark's observability surface) -------
    def folded_stats(self, workflow: str) -> WorkerStats:
        """Lifetime ``WorkerStats`` for the workflow: live shards plus
        cleanly-retired ones, all through ``WorkerStats.merge`` — the same
        folding helper ``ProcessShardPool`` uses, so the two runtimes cannot
        drift on what a lifetime total means."""
        total = WorkerStats()
        with self._lock:
            wp = self._wfs.get(workflow)
            if wp is None:
                return total
            total.merge(wp.retired)
            for w in wp.shards.values():
                total.merge(w.stats)
        return total

    def total_events_processed(self, workflow: str) -> int:
        return self.folded_stats(workflow).events_processed

    def total_fires(self, workflow: str) -> int:
        return self.folded_stats(workflow).fires

    def obs_snapshot(self, workflow: str) -> Dict[str, Any]:
        """The thread runtime's obs scrape: every live shard's registry
        snapshot merged (lock-free on the recording side — registries are
        per-shard), retired shards' counters folded back in, pool-level
        counters on top."""
        with self._lock:
            wp = self._wfs.get(workflow)
            workers = list(wp.shards.values()) if wp else []
            retired = wp.retired.snapshot() if wp else {}
            breaker = wp.breaker.snapshot() if wp else None
            pool_counters = {
                "tf_rebalance_total": wp.rebalances if wp else 0,
                "tf_shard_failures_total": wp.failures if wp else 0,
                "tf_circuit_open_total":
                    breaker["opened_total"] if breaker else 0,
            }
        snap = empty_snapshot()
        for w in workers:
            merge_snapshot(snap, w.metrics_snapshot())
        fold_counters(snap, {f"tf_{k}_total": v for k, v in retired.items()})
        fold_counters(snap, pool_counters)
        g = snap["gauges"]
        g["tf_restart_backoff_seconds"] = g.get("tf_restart_backoff_seconds", 0.0) \
            + (breaker["restart_backoff_seconds"] if breaker else 0.0)
        # host-loss fault domain (lease-fenced / replicated stores only):
        # fenced writes are a store-level counter (the threads share one
        # store instance), replication lag is the store client's deficit
        if getattr(self.event_store, "lease_owner", None) is not None:
            fold_counters(snap, {"tf_fenced_writes_total":
                                 self.event_store.fenced_writes})
        rep_stats = getattr(self.event_store, "replication_stats", None)
        if rep_stats is not None:
            try:
                g["tf_replication_lag_bytes"] = (
                    g.get("tf_replication_lag_bytes", 0)
                    + rep_stats()["lag_bytes"])
            except Exception:  # noqa: BLE001 - metrics must never raise
                # tfcheck: allow[seam-safety] scrape gauge is best-effort; a raising store stat must not break obs_snapshot
                pass
        return snap

    def metrics(self, workflow: str) -> Dict[str, Any]:
        with self._lock:
            wp = self._wfs.get(workflow)
            shards = dict(wp.shards) if wp else {}
            return {
                "shards": len(shards),
                "live_shards": self.live_shard_count(workflow),
                "shard_failures": wp.failures if wp else 0,
                "rebalances": wp.rebalances if wp else 0,
                "breaker": wp.breaker.snapshot() if wp else {},
                "generation": wp.group.generation if wp else 0,
                "assignment": {m: list(w.partitions or ()) for m, w in shards.items()},
                "partition_lags": self.event_store.partition_lags(workflow),
                "commit_offsets": self.event_store.commit_offsets(workflow),
                "events_processed": {
                    m: w.stats.events_processed for m, w in shards.items()},
                "total_lag": self.event_store.lag(workflow),
                "obs": self.obs_snapshot(workflow),
            }
