"""Replicated segment transport: the host-loss half of the durability story.

Every ``SegmentLog`` durability guarantee so far assumes the *disk*
survives — a SIGKILLed process leaves its torn tail behind and the next
locked writer repairs it.  A lost **node** (host plus its segment root) is
unrecoverable without a second copy.  This module is that second copy: a
thin length-prefixed TCP server/client pair that ships ``SegmentLog``
mutations (event/committed/DLQ segments, state-store delta logs, and the
small JSON meta files) from a partition's owner to a **replica root** — a
directory tree mirroring the primary's layout, byte for byte, on what would
be another host.

Protocol (4-byte big-endian length + JSON header, then ``dlen`` raw payload
bytes — segment data never pays JSON escaping; per-connection ordering;
acks are *cumulative*: the server applies every complete frame it has
buffered before acking, and a coalesced ack carries ``n``, the number of
frames it covers, plus the latest resulting size for that file):

* ``append {rel, off, data}`` — write ``data`` at byte ``off`` of
  ``<replica_root>/<rel>`` and truncate the file to ``off+len(data)``.
  ``off`` is the *primary's* offset for that append (serialized under the
  partition flock), so frames from different writer processes carry disjoint,
  totally-ordered ranges.  If the replica is missing bytes (``off`` past its
  EOF — a dropped frame or a fresh replica) the server NACKs with its
  current size and the client **heals**: it re-ships the gap straight from
  the shared local file, which is always authoritative.
* ``trunc {rel, size}`` — truncate (``size >= 0``) or remove (``size < 0``);
  mirrors torn-tail repair and log compaction.
* ``put {rel, data}`` — atomic whole-file replace; mirrors ``stream.json``
  and the state store's compacted JSON bases.

Acks carry the replica's resulting file size, so any successful ack is an
absolute **replication offset** — ``replica_lag()`` is simply shipped-bytes
minus acked-bytes, and a lost ack is healed by the next one.

Two client modes:

* ``sync=True`` — each ship blocks until acked (semi-sync replication).
  Deterministic, used by the chaos soaks: a replication fault surfaces at
  the exact append that triggered it.
* ``sync=False`` (default) — pipelined: ships are a single ``sendall``; a
  reader thread drains acks and heals NACKs in the background.  This is
  what keeps replication-on throughput within a few percent of
  replication-off (gated in ``scripts/perf_gate.py``).

Fault seams (``repro.chaos``): ``fault_hook("replicate.send", rel)`` fires
before a frame is shipped, ``fault_hook("replicate.ack", rel)`` before an
ack is applied — the seeded ``FaultPlan`` plugs in here.  A hook that
raises models a *lost frame/ack on the wire*: the local write already
happened and stays authoritative, the client counts the drop and moves on,
and the replica's gap NACK-heals on the next ack cycle (or an explicit
``heal_replication``).  Replication faults never crash a writer.
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple


def _send_frame(sock: socket.socket, obj: Dict[str, Any],
                payload: bytes = b"") -> None:
    """Ship a frame: length-prefixed JSON header + raw payload bytes.

    The payload (segment bytes) rides OUTSIDE the JSON so it is never
    escaped/re-encoded — header carries ``dlen`` so the receiver knows how
    much to read.  One ``sendall`` keeps the frame atomic per connection."""
    if payload:
        obj = dict(obj, dlen=len(payload))
    head = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    sock.sendall(struct.pack(">I", len(head)) + head + payload)


def _read_exact(rf, n: int) -> Optional[bytes]:
    buf = rf.read(n)
    if buf is None or len(buf) < n:
        return None
    return buf


def _recv_frame(rf) -> Optional[Dict[str, Any]]:
    """Read one frame from a buffered binary file-like (``sock.makefile``);
    a ``dlen`` header pulls that many raw payload bytes into ``data``."""
    head = _read_exact(rf, 4)
    if head is None:
        return None
    (n,) = struct.unpack(">I", head)
    body = _read_exact(rf, n)
    if body is None:
        return None
    msg = json.loads(body.decode("utf-8"))
    dlen = msg.get("dlen", 0)
    if dlen:
        payload = _read_exact(rf, dlen)
        if payload is None:
            return None
        msg["data"] = payload
    return msg


def _parse_frame(buf, pos: int, view: Optional[memoryview] = None):
    """Parse one frame starting at ``pos`` of ``buf`` (bytearray).

    Returns ``(msg, new_pos)``, or ``(None, pos)`` when the buffer holds
    only part of a frame (caller recvs more).  With ``view`` (a memoryview
    over ``buf``) the payload comes back as a zero-copy slice of it —
    valid only until the caller mutates ``buf``."""
    if len(buf) - pos < 4:
        return None, pos
    (n,) = struct.unpack_from(">I", buf, pos)
    if len(buf) - pos < 4 + n:
        return None, pos
    end = pos + 4 + n
    msg = json.loads(bytes(buf[pos + 4:end]).decode("utf-8"))
    dlen = msg.get("dlen", 0)
    if dlen:
        if len(buf) - end < dlen:
            return None, pos
        msg["data"] = (view[end:end + dlen] if view is not None
                       else bytes(buf[end:end + dlen]))
        end += dlen
    return msg, end


class ReplicaServer:
    """Accepts replication frames and applies them under a replica root.

    One thread per connection; applies are serialized by a global lock (the
    replica is a cold standby, not a serving path — correctness over
    concurrency).  ``fsync=False`` by default: the replica's job is to
    survive the *primary's* loss; its own power-loss durability can be
    turned on where it matters."""

    def __init__(self, replica_root: str, host: str = "127.0.0.1",
                 port: int = 0, fsync: bool = False) -> None:
        os.makedirs(replica_root, exist_ok=True)
        self.replica_root = os.path.abspath(replica_root)
        self.fsync = fsync
        self._lock = threading.Lock()
        self._files: Dict[str, Any] = {}
        self._sizes: Dict[str, int] = {}  # rel -> replica file size
        self._stopping = False
        self.frames = 0  # applied frames (all ops), for tests/diagnostics
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="replica-accept", daemon=True)
        self._accept_thread.start()

    # -- plumbing ------------------------------------------------------------
    def _path(self, rel: str) -> str:
        rel = os.path.normpath(rel)
        if os.path.isabs(rel) or rel.startswith(".."):
            raise ValueError("replication rel escapes the replica root: %r"
                             % rel)
        return os.path.join(self.replica_root, rel)

    def _handle(self, rel: str, path: str):
        f = self._files.get(rel)
        if f is None:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            if not os.path.exists(path):
                open(path, "ab").close()
            # buffering=0: raw FileIO — appends are already batch-sized, so
            # the BufferedRandom layer would only add a copy and a flush
            # syscall per frame, and raw writes release the GIL (on one
            # core every cycle the replica burns is stolen from the owner)
            f = self._files[rel] = open(path, "r+b", buffering=0)
        return f

    def _drop_handle(self, rel: str) -> None:
        f = self._files.pop(rel, None)
        if f is not None:
            try:
                f.close()
            except OSError:  # pragma: no cover
                pass

    # -- op application ------------------------------------------------------
    def _apply(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        op = msg.get("op")
        rel = msg["rel"]
        path = self._path(rel)
        with self._lock:
            self.frames += 1
            if op == "append":
                off = int(msg["off"])
                size = self._sizes.get(rel)
                if size is None:
                    try:
                        size = os.path.getsize(path)
                    except OSError:
                        size = 0
                if off > size:
                    # missing bytes (dropped frame / fresh replica): the
                    # client heals from the authoritative local file
                    return {"ok": False, "rel": rel, "size": size}
                data = msg.get("data") or b""
                end = off + len(data)
                f = self._handle(rel, path)
                f.seek(off)
                mv = memoryview(data)
                while mv:  # raw write may be partial (signals, rlimits)
                    mv = mv[f.write(mv):]
                if size > end:  # only an overwrite-shrink needs ftruncate
                    f.truncate(end)
                if self.fsync:
                    # tfcheck: allow[lock-discipline] fsync-before-ack is the replica's durability contract; _lock serializes appliers, no consumer hot path contends
                    os.fsync(f.fileno())
                self._sizes[rel] = end
                return {"ok": True, "rel": rel, "size": end}
            if op == "trunc":
                size = int(msg["size"])
                self._drop_handle(rel)
                self._sizes.pop(rel, None)
                if size < 0:
                    try:
                        os.remove(path)
                    except OSError:
                        pass
                    return {"ok": True, "rel": rel, "size": 0}
                try:
                    cur = os.path.getsize(path)
                except OSError:
                    cur = 0
                if size < cur:
                    with open(path, "r+b") as f:
                        f.truncate(size)
                self._sizes[rel] = min(size, cur)
                return {"ok": True, "rel": rel, "size": min(size, cur)}
            if op == "put":
                os.makedirs(os.path.dirname(path), exist_ok=True)
                tmp = path + ".rep.tmp"
                data = msg.get("data") or b""
                with open(tmp, "wb") as f:
                    f.write(data)
                    f.flush()
                    if self.fsync:
                        # tfcheck: allow[lock-discipline] fsync-before-ack is the replica's durability contract; _lock serializes appliers, no consumer hot path contends
                        os.fsync(f.fileno())
                os.replace(tmp, path)
                self._drop_handle(rel)
                self._sizes[rel] = len(data)
                return {"ok": True, "rel": rel, "size": len(data)}
            return {"ok": False, "rel": rel, "size": 0,
                    "error": "unknown op %r" % op}

    # -- connection handling -------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            # acks are tiny frames racing the client's stream: without
            # NODELAY Nagle holds them ~40ms and every drain pays it
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="replica-conn", daemon=True)
            t.start()

    def _serve(self, conn: socket.socket) -> None:
        # Manual receive buffer instead of makefile: applying every complete
        # frame before recv-ing again gives a natural ack-coalescing point.
        # Acks are flushed only when the input goes *idle* (a non-blocking
        # probe finds nothing queued), so a pipelined burst of appends to
        # one file costs ONE cumulative ack (``n`` = frames covered) rather
        # than one wakeup of the client's reader thread per frame — on a
        # small host that wakeup churn is the bulk of the transport's
        # overhead.  A semi-sync client ships one frame then waits, so its
        # probe is empty and it still gets a prompt per-frame ack.
        buf = bytearray(1 << 20)  # persistent: recv_into writes in place
        start = end = 0           # parse window [start, end)
        pending_ok: Dict[str, Dict[str, Any]] = {}  # rel -> cumulative ack
        pending_err: list = []
        try:
            while True:
                # zero-copy payloads: _apply consumes each slice before the
                # view is released and the window compacted
                view = memoryview(buf)[:end]
                try:
                    while True:
                        msg, start = _parse_frame(view, start, view)
                        if msg is None:
                            break
                        try:
                            ack = self._apply(msg)
                        except Exception as exc:  # noqa: BLE001 - keep serving
                            ack = {"ok": False, "rel": msg.get("rel", "?"),
                                   "size": 0, "error": repr(exc)}
                        if ack.get("ok"):
                            # per-rel cumulative: applies are in-order per
                            # rel, so the newest size subsumes the others
                            prev = pending_ok.get(ack["rel"])
                            if prev is not None:
                                ack["n"] = prev.get("n", 1) + 1
                            pending_ok[ack["rel"]] = ack
                        else:
                            pending_err.append(ack)
                finally:
                    view.release()
                if start == end:
                    start = end = 0
                elif start and len(buf) - end < (1 << 18):
                    buf[:end - start] = buf[start:end]  # memmove leftovers
                    end -= start
                    start = 0
                if len(buf) - end < (1 << 18):
                    buf.extend(bytes(max(1 << 20, len(buf))))  # grow
                try:
                    got = conn.recv_into(memoryview(buf)[end:],
                                         len(buf) - end, socket.MSG_DONTWAIT)
                except BlockingIOError:
                    if pending_ok or pending_err:
                        # one send for the whole batch of acks: ONE wakeup
                        # of the client's reader per idle point
                        out = bytearray()
                        for ack in list(pending_ok.values()) + pending_err:
                            head = json.dumps(
                                ack, separators=(",", ":")).encode("utf-8")
                            out += struct.pack(">I", len(head)) + head
                        pending_ok.clear()
                        pending_err.clear()
                        conn.sendall(out)
                    got = conn.recv_into(memoryview(buf)[end:],
                                         len(buf) - end)
                if not got:
                    return
                end += got
        except (OSError, ValueError):
            pass
        finally:
            try:
                conn.close()
            except OSError:  # pragma: no cover
                pass

    def sizes(self) -> Dict[str, int]:
        """Replica file sizes by rel path (diagnostics/tests)."""
        out: Dict[str, int] = {}
        for dirpath, _dirnames, filenames in os.walk(self.replica_root):
            for fn in filenames:
                p = os.path.join(dirpath, fn)
                out[os.path.relpath(p, self.replica_root)] = os.path.getsize(p)
        return out

    def close(self) -> None:
        self._stopping = True
        try:
            self._sock.close()
        except OSError:  # pragma: no cover
            pass
        with self._lock:
            for rel in list(self._files):
                self._drop_handle(rel)


class ReplicationClient:
    """Ships local ``SegmentLog`` mutations to a ``ReplicaServer``.

    Attach to a log with ``seg.replicator = client`` — ``SegmentLog`` then
    calls ``ship_append`` / ``ship_truncate`` / ``ship_remove`` after each
    durable local mutation.  ``replica_lag_bytes()`` is the acked
    replication offset deficit: bytes this client has shipped (or knows are
    local) minus bytes the replica has acknowledged."""

    def __init__(self, address: Tuple[str, int], primary_root: str,
                 sync: bool = False,
                 fault_hook: Optional[Callable[[str, str], None]] = None,
                 timeout: float = 10.0, prefix: str = "") -> None:
        self.address = (address[0], int(address[1]))
        self.primary_root = os.path.abspath(primary_root)
        # prefix: directory name prepended to every rel path, so several
        # primary trees (e.g. a deployment's bus/ and state/) can share one
        # replica root without colliding — the replica then mirrors the
        # whole deployment layout
        self.prefix = prefix.strip("/")
        self.sync = sync
        self.fault_hook = fault_hook
        self.timeout = timeout
        self._tx = threading.RLock()      # socket sends (and sync recv)
        self._state = threading.Lock()    # sent/acked counters
        self._cv = threading.Condition(self._state)
        self._sock: Optional[socket.socket] = None
        self._rfile = None                # buffered reader over _sock
        self._reader: Optional[threading.Thread] = None
        self._closed = False
        self.sent: Dict[str, int] = {}    # rel -> local end offset shipped
        self.acked: Dict[str, int] = {}   # rel -> replica size acked
        self._rel_cache: Dict[str, str] = {}
        self._pending = 0                 # unacked frames (async mode)
        # async mode batches frames in a local buffer and flushes in large
        # sendalls: on a small host every send wakes the replica thread, and
        # per-frame wakeups (GIL/scheduler convoy) dwarf the byte cost.  The
        # bytes are already durable locally, so a buffered frame lost with
        # the client is the same wire-loss case a dropped frame is: it shows
        # as replica lag and NACK-heals.  Flush: size/frame-count threshold,
        # a background flusher that bounds the age of the oldest buffered
        # frame (a trickle workload must not sit unreplicated until the next
        # ship), any drain(), and before a heal.
        #
        # Buffered frames stay UNSERIALIZED ([frame dict, payload] entries):
        # an append contiguous with the rel's last buffered append merges
        # into it, so a round-robin of partition segments ships a handful of
        # segment-sized frames instead of one per batch — per-frame cost
        # (header json, replica parse/apply/ack) is the transport's real
        # overhead, not the bytes.  Safe because appends to one rel carry
        # consecutive offsets and rels are independent; a put/trunc for a
        # rel breaks its merge chain (``_buf_tail``) to keep per-rel order.
        self._buf: list = []          # [frame dict, payload bytearray]
        self._buf_tail: Dict[str, list] = {}  # rel -> mergeable append entry
        self._buf_bytes = 0
        self._buf_t0 = 0.0
        self._flush_cv = threading.Condition(self._tx)
        self._flusher: Optional[threading.Thread] = None
        self.flush_bytes = 1 << 20
        self.flush_age = 0.02
        self.ships = 0
        self.errors = 0
        self.dropped = 0                  # frames/acks lost to fault_hook

    # -- wiring ---------------------------------------------------------------
    def _rel(self, path: str) -> str:
        rel = self._rel_cache.get(path)
        if rel is None:  # abspath+relpath syscall/normpath cost, paid once
            rel = os.path.relpath(os.path.abspath(path), self.primary_root)
            if self.prefix:
                rel = os.path.join(self.prefix, rel)
            self._rel_cache[path] = rel
        return rel

    def _local(self, rel: str) -> str:
        if self.prefix and rel.startswith(self.prefix + os.sep):
            rel = rel[len(self.prefix) + 1:]
        return os.path.join(self.primary_root, rel)

    def _ensure_sock(self) -> socket.socket:
        sock = self._sock
        if sock is None:
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.settimeout(self.timeout)
            # a send buffer comfortably above flush_bytes: a flush should be
            # one copy into the kernel, not a blocking ping-pong with the
            # replica thread every wmem-worth of bytes (sized pre-connect so
            # the window scales to it)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 1 << 21)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            try:
                sock.connect(self.address)
            except OSError:
                sock.close()
                raise
            self._sock = sock
            self._rfile = sock.makefile("rb")
            if not self.sync:
                sock.settimeout(None)  # the reader blocks across idle gaps
                self._reader = threading.Thread(
                    target=self._ack_loop, args=(sock, self._rfile),
                    name="replica-acks", daemon=True)
                self._reader.start()
        return sock

    def _drop_sock(self) -> None:
        sock, rfile = self._sock, self._rfile
        self._sock = self._rfile = None
        if sock is not None:
            # shutdown first: it unblocks a reader thread parked in
            # rfile.read() (which holds the buffer lock rfile.close() needs
            # — closing in the wrong order deadlocks against it)
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:  # pragma: no cover
                pass
        if rfile is not None:
            try:
                rfile.close()
            except (OSError, ValueError):  # pragma: no cover
                pass
        self._buf.clear()   # buffered frames are lost-on-wire: heal later
        self._buf_tail.clear()
        self._buf_bytes = 0
        with self._state:
            self._pending = 0
            self._cv.notify_all()

    # -- ack handling ---------------------------------------------------------
    def _read_gap(self, rel: str, start: int, end: int) -> bytes:
        path = self._local(rel)
        try:
            with open(path, "rb") as f:
                f.seek(start)
                return f.read(max(0, end - start))
        except OSError:
            return b""

    def _apply_ack(self, sock: socket.socket, ack: Dict[str, Any]) -> bool:
        """Record an ack; on NACK, heal the replica's gap from the local
        file (authoritative — all local writers share it).  Returns True if
        a heal frame was shipped (one more ack is in flight)."""
        if self.fault_hook is not None:
            try:
                self.fault_hook("replicate.ack", ack.get("rel", "?"))
            except Exception:  # noqa: BLE001 - injected: the ack is lost
                self.dropped += 1
                return False
        rel = ack.get("rel", "?")
        size = int(ack.get("size", 0))
        if ack.get("ok"):
            with self._state:
                if size > self.acked.get(rel, 0):
                    self.acked[rel] = size
            return False
        # NACK: replica is missing [size, sent[rel]) — re-ship it
        with self._state:
            end = self.sent.get(rel, 0)
        if end > size:
            gap = self._read_gap(rel, size, end)
            if gap:
                with self._tx:
                    # ordering: buffered frames carry older offsets — they
                    # must reach the replica before the heal bytes
                    self._flush_locked()
                    _send_frame(sock, {"op": "append", "rel": rel,
                                       "off": size}, gap)
                with self._state:
                    self._pending += 1
                return True
        return False

    def _ack_loop(self, sock: socket.socket, rfile) -> None:
        try:
            while True:
                ack = _recv_frame(rfile)
                if ack is None:
                    return
                try:
                    self._apply_ack(sock, ack)
                except Exception:  # noqa: BLE001 - injected/IO: drop the ack
                    self.errors += 1
                finally:
                    with self._state:
                        # a coalesced ack covers n frames (server batches
                        # while the pipe is busy); dropping its content
                        # loses the size update, never the accounting
                        self._pending -= int(ack.get("n", 1))
                        self._cv.notify_all()
        except OSError:
            pass

    # -- shipping -------------------------------------------------------------
    def _ship(self, frame: Dict[str, Any], rel: str,
              local_end: Optional[int], payload: bytes = b"") -> None:
        if self._closed:
            return
        if self.fault_hook is not None and frame["op"] == "append":
            try:
                self.fault_hook("replicate.send", rel)
            except Exception:  # noqa: BLE001 - injected: frame lost on wire
                self.dropped += 1
                with self._state:
                    # the bytes ARE local (the append preceded the ship), so
                    # the high-water mark advances and the deficit shows up
                    # as replica lag until a later ack NACK-heals the gap
                    if local_end is not None \
                            and local_end > self.sent.get(rel, 0):
                        self.sent[rel] = local_end
                return
        try:
            with self._tx:
                self.ships += 1
                with self._state:
                    if local_end is not None:
                        if local_end > self.sent.get(rel, 0):
                            self.sent[rel] = local_end
                    else:  # trunc/remove/put reset the high-water marks
                        self.sent.pop(rel, None)
                        self.acked.pop(rel, None)
                    if self.sync:
                        self._pending += 1
                if self.sync:
                    sock = self._ensure_sock()
                    _send_frame(sock, frame, payload)
                    outstanding = 1
                    while outstanding > 0:
                        ack = _recv_frame(self._rfile)
                        if ack is None:
                            raise ConnectionError("replica closed connection")
                        n = int(ack.get("n", 1))
                        with self._state:
                            self._pending -= n
                        outstanding -= n
                        if self._apply_ack(sock, ack):
                            outstanding += 1
                else:
                    if not self._buf:
                        self._buf_t0 = time.monotonic()
                        if self._flusher is None:
                            self._flusher = threading.Thread(
                                target=self._flush_loop,
                                name="replica-flush", daemon=True)
                            self._flusher.start()
                        self._flush_cv.notify()
                    tail = (self._buf_tail.get(rel)
                            if frame["op"] == "append" else None)
                    if (tail is not None
                            and tail[0]["off"] + len(tail[1])
                            == frame["off"]):
                        tail[1] += payload  # contiguous: extend the frame
                    elif frame["op"] == "append":
                        entry = [frame, bytearray(payload)]
                        self._buf.append(entry)
                        self._buf_tail[rel] = entry
                    else:
                        # put/trunc break the rel's merge chain (order!)
                        self._buf.append([frame, payload])
                        self._buf_tail.pop(rel, None)
                    self._buf_bytes += len(payload) + 64
                    # age is the flusher thread's job — only size/count
                    # thresholds here (no clock read on the hot path)
                    if (self._buf_bytes >= self.flush_bytes
                            or len(self._buf) >= 64):
                        self._flush_locked()
        except OSError:
            self.errors += 1
            self._drop_sock()

    def _flush_locked(self) -> None:
        """Serialize the buffered frames and send them in one sendall
        (``_tx`` held).  Pending-frame accounting happens here — a merged
        frame is ONE wire frame, acked once.  On failure the caller's
        ``_drop_sock`` clears the buffer and resets pending — partially-sent
        frames are wire losses that NACK-heal."""
        if not self._buf:
            return
        bufs = []
        n = 0
        for frame, payload in self._buf:
            if payload:
                frame = dict(frame, dlen=len(payload))
            head = json.dumps(frame, separators=(",", ":")).encode("utf-8")
            bufs.append(struct.pack(">I", len(head)) + head)
            if payload:
                bufs.append(payload)
            n += 1
        self._buf.clear()
        self._buf_tail.clear()
        self._buf_bytes = 0
        with self._state:
            self._pending += n
        sock = self._ensure_sock()
        # scatter-gather send: the kernel walks the frame list directly, no
        # flattened copy of the payload bytes.  Loop over partial sends.
        idx = off = 0
        while idx < len(bufs):
            first = bufs[idx]
            if off:
                first = memoryview(first)[off:]
            sent = sock.sendmsg([first] + bufs[idx + 1:])
            sent += off
            while idx < len(bufs) and sent >= len(bufs[idx]):
                sent -= len(bufs[idx])
                idx += 1
            off = sent

    def _flush_loop(self) -> None:
        """Background age bound: the oldest buffered frame is never more
        than ``flush_age`` from the wire, however slow the ship cadence —
        without this a trickle workload (or a shard about to be killed)
        could sit unreplicated behind the size threshold indefinitely."""
        with self._flush_cv:
            while not self._closed:
                if not self._buf:
                    self._flush_cv.wait()
                    continue
                left = self._buf_t0 + self.flush_age - time.monotonic()
                if left > 0:
                    self._flush_cv.wait(left)
                    continue
                try:
                    self._flush_locked()
                except OSError:
                    self.errors += 1
                    self._drop_sock()

    def flush(self) -> None:
        """Push buffered frames to the socket now (async mode ordering
        point).  A frame that reached the socket survives the *primary's*
        death — the replica keeps running and applies it — so ship-ordering
        across two clients (state vs bus) is established by flushing the
        first client before the second ships.  ``FileStateStore`` calls
        this after every checkpoint: the §3.4 checkpoint-before-commit
        contract must hold on the replica too, or a committed event whose
        state delta was still buffered loses its result to a host loss."""
        with self._tx:
            try:
                self._flush_locked()
            except OSError:
                self.errors += 1
                self._drop_sock()

    def ship_append(self, path: str, off: int, data) -> None:
        rel = self._rel(path)
        payload = data.encode("utf-8") if isinstance(data, str) else data
        self._ship({"op": "append", "rel": rel, "off": off},
                   rel, off + len(payload), payload)

    def ship_truncate(self, path: str, size: int) -> None:
        rel = self._rel(path)
        self._ship({"op": "trunc", "rel": rel, "size": size}, rel, None)

    def ship_remove(self, path: str) -> None:
        rel = self._rel(path)
        self._ship({"op": "trunc", "rel": rel, "size": -1}, rel, None)

    def ship_put(self, path: str, data) -> None:
        rel = self._rel(path)
        payload = data.encode("utf-8") if isinstance(data, str) else data
        self._ship({"op": "put", "rel": rel}, rel, None, payload)

    # -- lag ------------------------------------------------------------------
    def lag_by_rel(self) -> Dict[str, int]:
        """Unacked replication bytes per rel path (shipped minus acked)."""
        with self._state:
            return {rel: end - self.acked.get(rel, 0)
                    for rel, end in self.sent.items()
                    if end - self.acked.get(rel, 0) > 0}

    def replica_lag_bytes(self) -> int:
        return sum(self.lag_by_rel().values())

    def drain(self, timeout: float = 10.0) -> bool:
        """Wait until every shipped frame is acked (bounded).  Returns True
        if the pipeline drained."""
        deadline = time.monotonic() + timeout
        with self._tx:
            try:
                self._flush_locked()
            except OSError:
                self.errors += 1
                self._drop_sock()
        with self._state:
            while self._pending > 0:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cv.wait(left)
        return True

    def close(self) -> None:
        with self._tx:
            if self._sock is not None:  # best effort; never connect to close
                try:
                    self._flush_locked()
                except OSError:  # pragma: no cover
                    pass
        self._closed = True
        self._drop_sock()
        with self._flush_cv:
            self._flush_cv.notify_all()  # let the flusher thread exit
