"""The metrics plane: a dependency-free registry of counters, gauges and
pre-bucketed histograms built for the batch plane's O(batch) discipline.

Recording rule (the whole design): the hot path records **once per
(trigger, slice) or per batch**, never per event — ``observe_batch(n,
total_seconds)`` adds ``n`` observations in one call by crediting the
batch *mean* to a single pre-computed bucket.  A recording is two float
adds, one int add and one bisect over a tuple of bounds; there is no
locking anywhere on the write path.  Aggregation happens only on scrape:
each shard (thread or OS process) owns a private registry instance and
``merge_snapshot`` folds plain-dict snapshots together — snapshots are
what travels over the process pool's command pipe, so the scrape path is
identical for both runtimes.

Export is a hand-rolled Prometheus text rendering (no client library —
the container pins its dependency set) plus a JSON dump of the same
snapshot; both are wired into ``launch/serve.py`` and the pools'
``metrics()``.
"""
from __future__ import annotations

import json
from bisect import bisect_right
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

# Log-spaced latency bounds (seconds): 10µs → 10s covers everything from a
# noop fire-run call to a cold fsync on a loaded disk.  Upper bounds,
# ascending; the +inf bucket is implicit (counts[-1]).
DEFAULT_BOUNDS: Tuple[float, ...] = (
    1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 10.0)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    __slots__ = ("name", "bounds", "counts", "sum", "count")

    def __init__(self, name: str, bounds: Optional[Iterable[float]] = None) -> None:
        self.name = name
        self.bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_right(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def observe_batch(self, n: int, total: float) -> None:
        """One recording for ``n`` observations totalling ``total`` seconds:
        all ``n`` land in the bucket of the batch *mean* (the documented
        O(batch) approximation — per-event bucketing would reintroduce the
        per-event loop the batch plane exists to avoid)."""
        if n <= 0:
            return
        self.counts[bisect_right(self.bounds, total / n)] += n
        self.sum += total
        self.count += n


class MetricsRegistry:
    """Per-shard, get-or-create metric container.  Instances are private to
    one shard's hot loop (no locks); cross-shard totals exist only as merged
    snapshots produced at scrape time."""

    __slots__ = ("_counters", "_gauges", "_histograms")

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, bounds: Optional[Iterable[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, bounds)
        return h

    # -- scrape side ---------------------------------------------------------------
    def snapshot(self) -> Dict:
        """A plain-dict copy safe to serialize over a pipe and to merge."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "histograms": {
                n: {"bounds": list(h.bounds), "counts": list(h.counts),
                    "sum": h.sum, "count": h.count}
                for n, h in self._histograms.items()
            },
        }


def empty_snapshot() -> Dict:
    return {"counters": {}, "gauges": {}, "histograms": {}}


def merge_snapshot(into: Dict, snap: Optional[Mapping]) -> Dict:
    """Fold one shard's snapshot into an aggregate (in place; returns it).
    Counters and histogram buckets add; gauges add too — every gauge we
    export (lag, live shards) is a per-shard quantity whose fleet-wide value
    is the sum."""
    if not snap:
        return into
    c = into["counters"]
    for n, v in snap.get("counters", {}).items():
        c[n] = c.get(n, 0) + v
    g = into["gauges"]
    for n, v in snap.get("gauges", {}).items():
        g[n] = g.get(n, 0) + v
    hs = into["histograms"]
    for n, h in snap.get("histograms", {}).items():
        cur = hs.get(n)
        if cur is None or list(cur["bounds"]) != list(h["bounds"]):
            # first sight (or mismatched bounds: last writer wins whole)
            hs[n] = {"bounds": list(h["bounds"]), "counts": list(h["counts"]),
                     "sum": h["sum"], "count": h["count"]}
            continue
        cur["counts"] = [a + b for a, b in zip(cur["counts"], h["counts"])]
        cur["sum"] += h["sum"]
        cur["count"] += h["count"]
    return into


def fold_counters(into: Dict, counters: Mapping[str, int]) -> Dict:
    """Add loose ``{name: value}`` counters (e.g. a retired shard's folded
    ``WorkerStats``) into a snapshot's counter section."""
    c = into["counters"]
    for n, v in counters.items():
        c[n] = c.get(n, 0) + v
    return into


# -- export ------------------------------------------------------------------------
def render_prometheus(snap: Mapping) -> str:
    """Prometheus text exposition (0.0.4) of a snapshot — hand-rolled, no
    client library."""
    out: List[str] = []
    for name in sorted(snap.get("counters", {})):
        out.append(f"# TYPE {name} counter")
        out.append(f"{name} {snap['counters'][name]}")
    for name in sorted(snap.get("gauges", {})):
        out.append(f"# TYPE {name} gauge")
        out.append(f"{name} {snap['gauges'][name]}")
    for name in sorted(snap.get("histograms", {})):
        h = snap["histograms"][name]
        out.append(f"# TYPE {name} histogram")
        acc = 0
        for bound, n in zip(h["bounds"], h["counts"]):
            acc += n
            out.append(f'{name}_bucket{{le="{bound}"}} {acc}')
        out.append(f'{name}_bucket{{le="+Inf"}} {h["count"]}')
        out.append(f"{name}_sum {h['sum']}")
        out.append(f"{name}_count {h['count']}")
    return "\n".join(out) + "\n"


def render_json(snap: Mapping) -> str:
    return json.dumps(snap, indent=2, sort_keys=True)


def dump_metrics(snap: Mapping, prefix: str) -> List[str]:
    """Write ``<prefix>.prom`` + ``<prefix>.json``; returns the paths."""
    paths = []
    for suffix, text in ((".prom", render_prometheus(snap)),
                         (".json", render_json(snap))):
        path = prefix + suffix
        with open(path, "w", encoding="utf-8") as f:
            f.write(text)
        paths.append(path)
    return paths


class WorkerMetrics:
    """The worker's stage-boundary histograms, pre-bound so the hot loop
    pays attribute loads, not registry dict lookups.  One instance per
    ``TFWorker`` (= per shard)."""

    __slots__ = ("registry", "consume_lag", "batch_eval", "join_kernel",
                 "fire", "checkpoint", "publish")

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        r = self.registry = registry if registry is not None else MetricsRegistry()
        self.consume_lag = r.histogram("tf_consume_lag_seconds")
        self.batch_eval = r.histogram("tf_batch_eval_seconds")
        self.join_kernel = r.histogram("tf_join_kernel_seconds")
        self.fire = r.histogram("tf_fire_seconds")
        self.checkpoint = r.histogram("tf_checkpoint_seconds")
        self.publish = r.histogram("tf_publish_seconds")
