"""The causal trace plane: traceparent-style context on CloudEvents.

Every published event may carry a ``tftrace`` extension attribute —
``[trace_id, span_id]`` where ``span_id`` names the *span that produced
the event* (the workload's root publish span, or the fire span whose
action ``produce``d it).  A worker firing on a traced slice opens a
child span, runs the action with the new span as the current trace
context (so ``ctx.produce_batch`` stamps downstream events with it), and
records the span on completion — every downstream event therefore links
back to the fire that caused it, across shards, processes and crashes.

Spans are plain dicts collected in a bounded ring buffer
(``SpanCollector``) with a JSONL exporter.  Process-mode shards attach a
``SegmentLog`` sink: spans are flushed with the worker's checkpoint (so a
span is durable iff its batch's effects are), *plus* an early **open
record** (``dur: None``) written before a traced fire publishes children
— otherwise a SIGKILL between publish and checkpoint would leave orphan
child events pointing at a span no file ever saw.  Replay after the
crash re-runs the fire under a fresh span id, so stitching dedups by
``span_id`` (preferring the completed record over its open twin) and the
tree stays connected.

Sampling: the decision is made once, at the root.  A traced event is
always followed (context propagation is never sampled away mid-chain);
an *untraced* fire starts a new trace only when the tracer's sampler
admits it.  ``sample=1.0`` is full tracing, ``0.0`` is propagate-only.
"""
from __future__ import annotations

import json
import os
import time
import uuid
from collections import deque
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Sequence, Tuple

if TYPE_CHECKING:  # annotation-only: keeps obs free of core imports (no cycle)
    from ..core.events import CloudEvent

#: CloudEvents extension attribute carrying ``[trace_id, span_id]``.
EXT_KEY = "tftrace"


def new_id() -> str:
    """128-bit random hex halved — unique across forked shard processes
    (uuid4 reads the OS entropy pool, never a fork-shared PRNG state)."""
    return uuid.uuid4().hex[:16]


def trace_context(event: CloudEvent) -> Optional[Tuple[str, str]]:
    """The (trace_id, parent_span_id) an event carries, if any."""
    ext = event.ext
    if not ext:
        return None
    tc = ext.get(EXT_KEY)
    return (tc[0], tc[1]) if tc else None


def inject(events: Iterable[CloudEvent], trace_id: str, span_id: str) -> None:
    """Stamp trace context onto events that do not already carry one.
    Writes through ``__dict__`` (the events are frozen dataclasses — same
    trick as ``CloudEvent.from_dict``)."""
    tc = [trace_id, span_id]
    for e in events:
        if e.ext is None:
            e.__dict__["ext"] = {EXT_KEY: tc}
        else:
            e.ext.setdefault(EXT_KEY, tc)


class SpanCollector:
    """Bounded ring buffer of finished spans, with an optional durable
    ``SegmentLog`` sink (process-mode shards).  ``deque.append`` is atomic,
    so thread-pool shards share one collector lock-free."""

    def __init__(self, capacity: int = 8192, segment=None) -> None:
        self.spans: deque = deque(maxlen=capacity)
        self._segment = segment
        self._pending: List[dict] = []

    def add(self, span: dict) -> None:
        self.spans.append(span)
        if self._segment is not None:
            self._pending.append(span)

    def flush(self) -> None:
        """Append pending spans to the segment sink (one write + fsync per
        flush — called from the worker's checkpoint, so span durability
        rides the checkpoint's fsync cadence, not per-span)."""
        if self._segment is None or not self._pending:
            return
        lines = [json.dumps(s, separators=(",", ":")) for s in self._pending]
        self._pending.clear()
        self._segment.append(lines)

    def persist_now(self, span: dict) -> None:
        """Durably append one record immediately (the open-record path)."""
        if self._segment is not None:
            self._segment.append([json.dumps(span, separators=(",", ":"))])

    def drain(self) -> List[dict]:
        out = list(self.spans)
        self.spans.clear()
        return out

    def export_jsonl(self, path: str) -> int:
        with open(path, "w", encoding="utf-8") as f:
            for s in self.spans:
                f.write(json.dumps(s, separators=(",", ":")) + "\n")
        return len(self.spans)


class Tracer:
    """Per-shard span factory.  ``sample`` admits *new* roots via a
    deterministic accumulator (no RNG on the hot path); propagation of an
    existing context is unconditional."""

    __slots__ = ("sample", "collector", "tag", "_acc")

    def __init__(self, sample: float = 0.1,
                 collector: Optional[SpanCollector] = None,
                 tag: Optional[str] = None) -> None:
        self.sample = max(0.0, min(1.0, sample))
        self.collector = collector if collector is not None else SpanCollector()
        self.tag = tag
        self._acc = 0.0

    def sample_new(self) -> bool:
        self._acc += self.sample
        if self._acc >= 1.0:
            self._acc -= 1.0
            return True
        return False

    # -- span lifecycle ------------------------------------------------------------
    def begin(self, name: str, trace_id: str, parent_id: Optional[str],
              **attrs) -> dict:
        span = {"trace": trace_id, "span": new_id(), "parent": parent_id,
                "name": name, "ts": time.time(), "dur": None}
        if self.tag is not None:
            span["shard"] = self.tag
        if attrs:
            span.update(attrs)
        span["_t0"] = time.perf_counter()
        return span

    def end(self, span: dict) -> None:
        span["dur"] = time.perf_counter() - span.pop("_t0")
        span.pop("_open", None)
        self.collector.add(span)

    def start_trace(self, name: str, **attrs) -> dict:
        """Open a root span (e.g. the workload's publish step).  The caller
        injects ``context_of_span(root)`` into the events it publishes and
        ``end()``s the root when done."""
        return self.begin(name, new_id(), None, **attrs)

    def fire_span(self, event: CloudEvent, trigger_id: str, workflow: str,
                  n: int) -> Optional[dict]:
        """Open a fire span for a (trigger, slice): child of the slice's
        carried context, or a sampled new root when the slice is untraced.
        Returns None when tracing declines (unsampled, untraced)."""
        tc = trace_context(event)
        if tc is not None:
            trace_id, parent = tc
        elif self.sample_new():
            trace_id, parent = new_id(), None
        else:
            return None
        return self.begin("fire", trace_id, parent,
                          wf=workflow, trigger=trigger_id, n=n)

    def persist_open(self, span: dict) -> None:
        """Durably record a still-open span (``dur: None``) before its fire
        publishes child events — the completed record written later shares
        the span id and wins at stitch time."""
        if self.collector._segment is None:
            return  # in-memory collectors have nothing to make durable
        if "_open" not in span:  # once per span
            span["_open"] = True
            open_rec = {k: v for k, v in span.items()
                        if k not in ("_t0", "_open")}
            self.collector.persist_now(open_rec)

    def flush(self) -> None:
        self.collector.flush()


def context_of_span(span: dict) -> Tuple[str, str]:
    return span["trace"], span["span"]


# -- stitching ---------------------------------------------------------------------
def load_spans(paths: Sequence[str]) -> List[dict]:
    """Read span records from JSONL files / directories of ``*.jsonl``.
    Tolerates the SegmentLog torn-tail (a SIGKILL mid-append): unparseable
    lines end that file's scan, matching the log's own contract."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(
                os.path.join(p, f) for f in os.listdir(p) if f.endswith(".jsonl")))
        else:
            files.append(p)
    spans: List[dict] = []
    for path in files:
        try:
            with open(path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        spans.append(json.loads(line))
                    except ValueError:
                        break  # torn tail — everything before it is valid
        except OSError:
            continue
    return spans


def stitch_spans(*span_sets: Iterable[dict]) -> List[dict]:
    """Merge span records, deduplicating by span id.  A completed record
    (``dur`` set) always replaces its open twin; duplicate completed records
    (re-read segments) collapse to one."""
    by_id: Dict[str, dict] = {}
    for spans in span_sets:
        for s in spans:
            sid = s.get("span")
            if sid is None:
                continue
            cur = by_id.get(sid)
            if cur is None or (cur.get("dur") is None and s.get("dur") is not None):
                by_id[sid] = s
    return sorted(by_id.values(), key=lambda s: s.get("ts", 0.0))


def span_trees(spans: Sequence[dict]) -> Dict[str, dict]:
    """Group stitched spans into one tree per trace id.  Each tree is
    ``{"root": attachment, "spans": n, "children": {...}, "attachments": k}``
    where an *attachment point* is a parent id no span in the set owns
    (the workload's root context, typically) or ``None`` for explicit
    roots; a connected trace has exactly one."""
    trees: Dict[str, dict] = {}
    for trace_id in {s["trace"] for s in spans}:
        trace = [s for s in spans if s["trace"] == trace_id]
        ids = {s["span"] for s in trace}
        children: Dict[Optional[str], List[dict]] = {}
        attachments = set()
        for s in trace:
            parent = s.get("parent")
            if parent not in ids:
                attachments.add(parent)
            children.setdefault(parent, []).append(s)
        trees[trace_id] = {
            "spans": len(trace),
            "attachments": sorted(str(a) for a in attachments),
            "connected": len(attachments) == 1,
            "children": children,
        }
    return trees


def render_tree(tree: dict, spans: Sequence[dict]) -> str:
    """ASCII rendering of one trace's span tree (depth-first)."""
    children = tree["children"]
    ids = {s["span"]: s for s in spans}
    lines: List[str] = []

    def walk(parent: Optional[str], depth: int) -> None:
        for s in sorted(children.get(parent, ()), key=lambda x: x.get("ts", 0.0)):
            dur = s.get("dur")
            dur_s = f"{dur * 1e3:.2f}ms" if dur is not None else "open"
            label = s.get("name", "?")
            extra = " ".join(
                f"{k}={s[k]}" for k in ("wf", "trigger", "n", "shard") if k in s)
            lines.append(f"{'  ' * depth}- {label} [{s['span']}] {dur_s}"
                         + (f" ({extra})" if extra else ""))
            walk(s["span"], depth + 1)

    roots = [a for a in {s.get("parent") for s in spans if s["span"] in ids}
             if a not in ids]
    for attachment in sorted(str(r) for r in set(roots)):
        real = None if attachment == "None" else attachment
        lines.append(f"root <- {attachment}")
        walk(real, 1)
    return "\n".join(lines)
