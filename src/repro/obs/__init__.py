"""Observability: the metrics plane (``obs.metrics``) and the causal
trace plane (``obs.trace``) — see docs/ARCHITECTURE.md §7."""
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    WorkerMetrics,
    dump_metrics,
    empty_snapshot,
    fold_counters,
    merge_snapshot,
    render_json,
    render_prometheus,
)
from .trace import (
    EXT_KEY,
    SpanCollector,
    Tracer,
    context_of_span,
    inject,
    load_spans,
    render_tree,
    span_trees,
    stitch_spans,
    trace_context,
)

__all__ = [
    "Counter", "EXT_KEY", "Gauge", "Histogram", "MetricsRegistry",
    "SpanCollector", "Tracer", "WorkerMetrics", "context_of_span",
    "dump_metrics", "empty_snapshot", "fold_counters", "inject",
    "load_spans", "merge_snapshot", "render_json", "render_prometheus",
    "render_tree", "span_trees", "stitch_spans", "trace_context",
]
