"""Fig 8 reproduction: TF-Workers auto-scale with workflow activity,
including scale-to-zero during long-running actions.

40 synthetic workflows (paper: 115) publish events, pause (simulating a long
external task), resume, and stop.  The KEDA-style autoscaler samples
(t, active_workers, lag) — the timeline is the figure's data.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

from repro.core import (KedaAutoscaler, Triggerflow, make_trigger,
                        termination_event)

N_WORKFLOWS = 40
BURST_EVENTS = 150
PAUSE_S = 0.7
GRACE_S = 0.25


def _publisher(tf: Triggerflow, wf: str, stop_evt: threading.Event) -> None:
    for phase in range(2):
        for i in range(BURST_EVENTS):
            tf.publish(wf, termination_event("tick", i))
            time.sleep(0.002)
        time.sleep(PAUSE_S)  # long-running action: workers should reclaim
    stop_evt.set()


def run() -> List[Dict]:
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    for i in range(N_WORKFLOWS):
        wf = f"wf{i}"
        tf.create_workflow(wf)
        tf.add_trigger(wf, make_trigger(
            "tick", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"{wf}/t", transient=False))
    scaler = KedaAutoscaler(tf, poll_interval=0.05, grace_period=GRACE_S,
                            max_workers=64).start()
    stops = []
    threads = []
    t0 = time.time()
    for i in range(N_WORKFLOWS):
        ev = threading.Event()
        stops.append(ev)
        th = threading.Thread(target=_publisher, args=(tf, f"wf{i}", ev), daemon=True)
        threads.append(th)
        th.start()
        if i == N_WORKFLOWS // 2:
            time.sleep(1.0)  # second wave, as in the paper's staged starts
    for th in threads:
        th.join()
    # drain
    deadline = time.time() + 10
    while time.time() < deadline:
        if all(tf.event_store.lag(f"wf{i}") == 0 for i in range(N_WORKFLOWS)):
            break
        time.sleep(0.05)
    time.sleep(GRACE_S * 3)  # let scale-to-zero happen
    scaler._tick()
    scaler.stop()
    total_t = time.time() - t0
    peak = max(w for _, w, _ in scaler.timeline)
    zeros = sum(1 for _, w, _ in scaler.timeline if w == 0)
    final = scaler.timeline[-1][1]
    tf.shutdown()
    processed = sum(tf.worker(f"wf{i}").stats.events_processed
                    for i in range(N_WORKFLOWS))
    return [{
        "name": "autoscaling.keda",
        "us_per_call": total_t / max(processed, 1) * 1e6,
        "derived": (f"peak_workers={peak} final_workers={final} "
                    f"scale_ups={scaler.scale_ups} scale_downs={scaler.scale_downs} "
                    f"zero_samples={zeros} events={processed} wall={total_t:.1f}s"),
        "timeline": scaler.timeline[-200:],
    }]
