"""§Roofline collector: reads results/dryrun/*.json and emits the
per-(arch × shape) baseline table rows + the markdown table for
EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh: str = "single") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*_{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fraction_of_roofline(cell: dict) -> float:
    """Achievable fraction: ideal compute time / modelled step time
    (bounded by the max of the three terms, assuming perfect overlap)."""
    t = cell["roofline"]
    ideal = cell["model_flops"] / cell["n_devices"] / 197e12
    step_t = max(t["t_compute"], t["t_memory"], t["t_collective"])
    return ideal / step_t if step_t else 0.0


def markdown_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | dominant | "
            "useful_flops | roofline_frac | HBM GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh):
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | skipped "
                        f"(long-context n/a) | — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | FAILED: {c['error'][:60]} "
                        f"| | | | | | |")
            continue
        t = c["roofline"]
        frac = fraction_of_roofline(c)
        mem_gb = c["memory"]["peak_est_bytes"] / 2 ** 30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {t['t_compute']:.3e} | "
            f"{t['t_memory']:.3e} | {t['t_collective']:.3e} | {c['dominant'][2:]} | "
            f"{c['useful_flops_ratio']:.2f} | {frac:.3f} | {mem_gb:.1f} |")
    return "\n".join(rows)


def run() -> List[Dict]:
    out = []
    for c in load_cells("single"):
        if c["status"] != "ok":
            continue
        t = c["roofline"]
        frac = fraction_of_roofline(c)
        out.append({
            "name": f"roofline.{c['arch']}.{c['shape']}",
            "us_per_call": max(t["t_compute"], t["t_memory"],
                               t["t_collective"]) * 1e6,
            "derived": (f"dom={c['dominant'][2:]} frac={frac:.3f} "
                        f"useful={c['useful_flops_ratio']:.2f} "
                        f"mem={c['memory']['peak_est_bytes'] / 2**30:.1f}GB"),
        })
    return out
