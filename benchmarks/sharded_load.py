"""repro.bus reproduction: events/s vs worker-shard count.

The Table 1 scenario (noop triggers, §6.1) run on the sharded dataplane:
events are keyed over ``subjects`` distinct trigger subjects, routed onto a
partitioned event bus, and drained by {1, 2, 4, 8} ShardWorker shards running
on their own threads.  The single-worker ``load_test.bench_noop`` figure on
the same machine (action plane on, like the shards) is reported as the
baseline; multi-shard rows also report scaling vs the 1-shard row — the
apples-to-apples number (same subjects/partitions/store), and the one the
store's lock granularity governs.

Shard throughput comes from the consumer-group fast path (exclusive
partition ownership ⇒ no per-event committed checks, O(batch) prefix commits
against short per-partition logs) plus overlapping shard batches; on
GIL-bound boxes with few cores, thread shards cannot beat the interpreter's
serial ceiling, which is what the striped-vs-global-lock contention rows
(4 shards, batch 256) isolate: same workload, only the lock granularity
changes.

``--mode=process`` runs the same workload on the multiprocess runtime
(``ProcessShardPool`` over the durable ``FilePartitionedEventStore``):
each shard is an OS process with its own interpreter, consuming and
committing through per-partition file-locked segment logs.  Unlike thread
shards (which share the publisher's in-memory mirror and never touch a
codec), every shard process pays real event deserialization — the same cost
the paper's TF-Workers pay consuming from Kafka — so the process rows
measure *scaling past the GIL net of serialization*.  The derived fields
report per-shard CPU seconds alongside wall throughput: on kernels where
multiprocess Python scales (any normal box with ≥2 cores), process shards
pass thread shards as soon as cores × per-core file throughput exceeds the
GIL ceiling; sandboxed kernels that serialize allocation-heavy processes
(gVisor-style) cap the wall-clock win regardless of core count, which the
cpu/wall split makes visible instead of hiding.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time
from typing import Dict, List

from repro.bus import PartitionedEventStore, ProcessShardPool
from repro.core import Triggerflow, make_trigger, termination_event

from benchmarks.load_test import bench_join, bench_noop

SHARD_COUNTS = (1, 2, 4, 8)
PROC_SHARD_COUNTS = (1, 2, 4)


def bench_sharded_noop(
    n_events: int = 100_000,
    shards: int = 4,
    partitions: int = 16,
    subjects: int = 64,
    batch_size: int = 4096,
    striped: bool = True,
) -> Dict:
    """``striped=False`` serializes every partition behind one lock — the
    pre-striping store, kept as the contention baseline.  Small
    ``batch_size`` values raise the store-call rate and make the lock
    granularity visible."""
    store = PartitionedEventStore(partitions, striped=striped)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.create_workflow("load")
    for s in range(subjects):
        tf.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    events = [termination_event(f"e{i % subjects}", i) for i in range(n_events)]
    store.publish_batch("load", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("load", shards)
    while store.lag("load") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    processed = tf.pool.total_events_processed("load")
    assert processed >= n_events, (processed, n_events)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions}


def bench_proc_noop(
    n_events: int = 100_000,
    shards: int = 4,
    partitions: int = 16,
    subjects: int = 64,
    batch_size: int = 4096,
    fsync: bool = False,
    root: str = None,
) -> Dict:
    """The Table-1 noop workload on the multiprocess runtime: ``shards`` OS
    processes over the durable file-backed bus.  ``fsync=False`` is the
    Kafka-default-flush analogy (the page cache survives the SIGKILL crash
    mode; power-loss durability costs the extra fsyncs)."""
    own_root = root is None
    root = root or tempfile.mkdtemp(prefix="tf-procbench-")
    pool = ProcessShardPool(root, num_partitions=partitions,
                            batch_size=batch_size, fsync=fsync)
    pool.create_workflow("load")
    for s in range(subjects):
        pool.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    events = [termination_event(f"e{i % subjects}", i) for i in range(n_events)]
    pool.publish_batch("load", events)

    t0 = time.perf_counter()
    pool.start_shards("load", shards)
    pool.wait_drained("load", timeout=600, poll=0.02)
    dt = time.perf_counter() - t0
    stats = pool._stats("load")
    processed = sum(s.get("events_processed", 0) for s in stats.values())
    cpu = sum(s.get("cpu_seconds", 0.0) for s in stats.values())
    pool.stop_all()
    if own_root:
        shutil.rmtree(root, ignore_errors=True)
    assert processed >= n_events, (processed, n_events)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions,
            "shard_cpu_seconds": cpu}


def bench_sharded_join(
    n_triggers: int = 100,
    events_each: int = 1000,
    shards: int = 4,
    partitions: int = 16,
    batch_size: int = 4096,
    batch_plane: bool = True,
) -> Dict:
    """The Table-1 join workload on the sharded dataplane: proves the batch
    plane (grouped slices + vectorized triage) composes with partitioned
    shards — each shard triages its own partitions' share of the batch.
    ``batch_plane=False`` is the interpreter-on-shards control."""
    store = PartitionedEventStore(partitions)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.pool.batch_plane = batch_plane
    tf.create_workflow("join")
    for t in range(n_triggers):
        tf.add_trigger("join", make_trigger(
            f"j{t}",
            condition={"name": "counter", "expected": events_each,
                       "aggregate": False},
            action={"name": "noop"}, trigger_id=f"jt{t}", transient=False))
    n_events = n_triggers * events_each
    events = [termination_event(f"j{i % n_triggers}", i) for i in range(n_events)]
    store.publish_batch("join", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("join", shards)
    while store.lag("join") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    fired = tf.pool.total_fires("join")
    assert fired == n_triggers, (fired, n_triggers)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions, "fired": fired}


def run(reps: int = 3, n_events: int = 100_000,
        mode: str = "all") -> List[Dict]:
    # Interleave scenarios across repetitions and keep the best events/s per
    # scenario: single-run numbers on small shared machines swing ±25% from
    # CPU steal, which would drown the architectural deltas being measured.
    rows: List[Dict] = []
    if mode in ("all", "thread"):
        rows.extend(_run_thread(reps, n_events))
    if mode in ("all", "process"):
        thread4 = next((r["events_per_s"] for r in rows
                        if r["name"] == "sharded_load.noop_4shard"), None)
        rows.extend(_run_process(reps, n_events, thread4_noop=thread4))
    return rows


def _run_thread(reps: int, n_events: int) -> List[Dict]:
    best: Dict = {"baseline": 0.0, "contention_striped": 0.0,
                  "contention_coarse": 0.0}
    best.update({s: 0.0 for s in SHARD_COUNTS})
    for _ in range(reps):
        # baseline runs the same plane configuration as the shards (action
        # plane on), so shard-count rows measure scaling, not plane deltas
        best["baseline"] = max(
            best["baseline"],
            bench_noop(n_events, action_plane=True)["events_per_s"])
        for shards in SHARD_COUNTS:
            r = bench_sharded_noop(n_events=n_events, shards=shards)
            best[shards] = max(best[shards], r["events_per_s"])
        # store-lock contention A/B: 4 shards, small batches (high store-call
        # rate), striped per-partition locks vs the old global lock
        for key, striped in (("contention_striped", True),
                             ("contention_coarse", False)):
            r = bench_sharded_noop(n_events=n_events, shards=4,
                                   batch_size=256, striped=striped)
            best[key] = max(best[key], r["events_per_s"])

    rows = [{
        "name": "sharded_load.baseline_single_worker",
        "us_per_call": 1e6 / best["baseline"],
        "events_per_s": best["baseline"],
        "derived": f"{best['baseline']:.0f} events/s (bench_noop, best of {reps})",
    }]
    for shards in SHARD_COUNTS:
        speedup = best[shards] / best["baseline"]
        scaling = best[shards] / best[1]
        rows.append({
            "name": f"sharded_load.noop_{shards}shard",
            "us_per_call": 1e6 / best[shards],
            "events_per_s": best[shards],
            "derived": f"{best[shards]:.0f} events/s "
                       f"({speedup:.2f}x vs single worker, "
                       f"{scaling:.2f}x vs 1 shard)",
        })
    coarse = best["contention_coarse"]
    striped = best["contention_striped"]
    rows.append({
        "name": "sharded_load.noop_4shard_contention_coarse",
        "us_per_call": 1e6 / coarse,
        "events_per_s": coarse,
        "derived": f"{coarse:.0f} events/s (4 shards, batch 256, one global "
                   f"store lock)",
    })
    rows.append({
        "name": "sharded_load.noop_4shard_contention",
        "us_per_call": 1e6 / striped,
        "events_per_s": striped,
        "derived": f"{striped:.0f} events/s "
                   f"({striped / coarse:.2f}x vs global lock; 4 shards, "
                   f"batch 256, striped per-partition locks)",
    })
    # Batch plane × sharding composition: the same 4-shard deployment with
    # the interpreter vs the batch plane (the latter must not regress).
    join_interp = join_batch = 0.0
    for _ in range(reps):
        join_interp = max(join_interp,
                          bench_sharded_join(batch_plane=False)["events_per_s"])
        join_batch = max(join_batch,
                         bench_sharded_join(batch_plane=True)["events_per_s"])
    join_single = bench_join()["events_per_s"]
    rows.append({
        "name": "sharded_load.join_4shard_interpreter",
        "us_per_call": 1e6 / join_interp,
        "events_per_s": join_interp,
        "derived": f"{join_interp:.0f} events/s (per-event interpreter on "
                   f"4 shards)",
    })
    rows.append({
        "name": "sharded_load.join_4shard",
        "us_per_call": 1e6 / join_batch,
        "events_per_s": join_batch,
        "derived": f"{join_batch:.0f} events/s "
                   f"({join_batch / join_interp:.2f}x vs interpreter shards, "
                   f"{join_batch / join_single:.2f}x vs 1 batch-plane worker)",
    })
    return rows


def _run_process(reps: int, n_events: int,
                 thread4_noop: float = None) -> List[Dict]:
    """Process-mode rows: the same noop workload on ``ProcessShardPool``
    over the durable file bus.  Reports wall events/s, the ratio against the
    threaded 4-shard row (when available), per-count scaling vs 1 process,
    and the aggregate shard-CPU seconds (cpu ≈ wall·shards ⇒ the kernel ran
    the processes in parallel; cpu ≈ wall ⇒ it serialized them)."""
    best: Dict[int, Dict] = {}
    for _ in range(reps):
        for shards in PROC_SHARD_COUNTS:
            r = bench_proc_noop(n_events=n_events, shards=shards)
            if shards not in best or r["events_per_s"] > best[shards]["events_per_s"]:
                best[shards] = r
    rows: List[Dict] = []
    base = best[PROC_SHARD_COUNTS[0]]["events_per_s"]
    for shards in PROC_SHARD_COUNTS:
        r = best[shards]
        eps = r["events_per_s"]
        vs_thread = (f", {eps / thread4_noop:.2f}x vs threaded 4-shard"
                     if thread4_noop else "")
        rows.append({
            "name": f"sharded_load.noop_{shards}proc_file",
            "us_per_call": 1e6 / eps,
            "events_per_s": eps,
            "derived": f"{eps:.0f} events/s ({shards} shard processes over "
                       f"the durable file bus; {eps / base:.2f}x vs 1 process"
                       f"{vs_thread}; shard-cpu {r['shard_cpu_seconds']:.2f}s "
                       f"over {r['seconds']:.2f}s wall)",
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("thread", "process", "all"),
                    default="all",
                    help="thread: ShardedWorkerPool over the in-memory bus; "
                         "process: ProcessShardPool over the file bus")
    ap.add_argument("--events", type=int, default=100_000)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    for row in run(reps=args.reps, n_events=args.events, mode=args.mode):
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
