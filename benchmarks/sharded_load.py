"""repro.bus reproduction: events/s vs worker-shard count.

The Table 1 scenario (noop triggers, §6.1) run on the sharded dataplane:
events are keyed over ``subjects`` distinct trigger subjects, routed onto a
partitioned event bus, and drained by {1, 2, 4, 8} ShardWorker shards running
on their own threads.  The single-worker ``load_test.bench_noop`` figure on
the same machine is reported as the baseline the 4-shard run must beat.

Shard throughput wins come from the consumer-group fast path (exclusive
partition ownership ⇒ no per-event committed checks, O(batch) prefix commits
against short per-partition logs) plus overlapping shard batches.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.bus import PartitionedEventStore
from repro.core import Triggerflow, make_trigger, termination_event

from benchmarks.load_test import bench_join, bench_noop

SHARD_COUNTS = (1, 2, 4, 8)


def bench_sharded_noop(
    n_events: int = 100_000,
    shards: int = 4,
    partitions: int = 16,
    subjects: int = 64,
    batch_size: int = 4096,
) -> Dict:
    store = PartitionedEventStore(partitions)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.create_workflow("load")
    for s in range(subjects):
        tf.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    events = [termination_event(f"e{i % subjects}", i) for i in range(n_events)]
    store.publish_batch("load", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("load", shards)
    while store.lag("load") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    processed = tf.pool.total_events_processed("load")
    assert processed >= n_events, (processed, n_events)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions}


def bench_sharded_join(
    n_triggers: int = 100,
    events_each: int = 1000,
    shards: int = 4,
    partitions: int = 16,
    batch_size: int = 4096,
    batch_plane: bool = True,
) -> Dict:
    """The Table-1 join workload on the sharded dataplane: proves the batch
    plane (grouped slices + vectorized triage) composes with partitioned
    shards — each shard triages its own partitions' share of the batch.
    ``batch_plane=False`` is the interpreter-on-shards control."""
    store = PartitionedEventStore(partitions)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.pool.batch_plane = batch_plane
    tf.create_workflow("join")
    for t in range(n_triggers):
        tf.add_trigger("join", make_trigger(
            f"j{t}",
            condition={"name": "counter", "expected": events_each,
                       "aggregate": False},
            action={"name": "noop"}, trigger_id=f"jt{t}", transient=False))
    n_events = n_triggers * events_each
    events = [termination_event(f"j{i % n_triggers}", i) for i in range(n_events)]
    store.publish_batch("join", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("join", shards)
    while store.lag("join") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    fired = tf.pool.total_fires("join")
    assert fired == n_triggers, (fired, n_triggers)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions, "fired": fired}


def run(reps: int = 3, n_events: int = 100_000) -> List[Dict]:
    # Interleave scenarios across repetitions and keep the best events/s per
    # scenario: single-run numbers on small shared machines swing ±25% from
    # CPU steal, which would drown the architectural deltas being measured.
    best: Dict = {"baseline": 0.0}
    best.update({s: 0.0 for s in SHARD_COUNTS})
    for _ in range(reps):
        best["baseline"] = max(best["baseline"],
                               bench_noop(n_events)["events_per_s"])
        for shards in SHARD_COUNTS:
            r = bench_sharded_noop(n_events=n_events, shards=shards)
            best[shards] = max(best[shards], r["events_per_s"])

    rows = [{
        "name": "sharded_load.baseline_single_worker",
        "us_per_call": 1e6 / best["baseline"],
        "events_per_s": best["baseline"],
        "derived": f"{best['baseline']:.0f} events/s (bench_noop, best of {reps})",
    }]
    for shards in SHARD_COUNTS:
        speedup = best[shards] / best["baseline"]
        rows.append({
            "name": f"sharded_load.noop_{shards}shard",
            "us_per_call": 1e6 / best[shards],
            "events_per_s": best[shards],
            "derived": f"{best[shards]:.0f} events/s "
                       f"({speedup:.2f}x vs single worker)",
        })
    # Batch plane × sharding composition: the same 4-shard deployment with
    # the interpreter vs the batch plane (the latter must not regress).
    join_interp = join_batch = 0.0
    for _ in range(reps):
        join_interp = max(join_interp,
                          bench_sharded_join(batch_plane=False)["events_per_s"])
        join_batch = max(join_batch,
                         bench_sharded_join(batch_plane=True)["events_per_s"])
    join_single = bench_join()["events_per_s"]
    rows.append({
        "name": "sharded_load.join_4shard_interpreter",
        "us_per_call": 1e6 / join_interp,
        "events_per_s": join_interp,
        "derived": f"{join_interp:.0f} events/s (per-event interpreter on "
                   f"4 shards)",
    })
    rows.append({
        "name": "sharded_load.join_4shard",
        "us_per_call": 1e6 / join_batch,
        "events_per_s": join_batch,
        "derived": f"{join_batch:.0f} events/s "
                   f"({join_batch / join_interp:.2f}x vs interpreter shards, "
                   f"{join_batch / join_single:.2f}x vs 1 batch-plane worker)",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
