"""repro.bus reproduction: events/s vs worker-shard count.

The Table 1 scenario (noop triggers, §6.1) run on the sharded dataplane:
events are keyed over ``subjects`` distinct trigger subjects, routed onto a
partitioned event bus, and drained by {1, 2, 4, 8} ShardWorker shards running
on their own threads.  The single-worker ``load_test.bench_noop`` figure on
the same machine is reported as the baseline the 4-shard run must beat.

Shard throughput wins come from the consumer-group fast path (exclusive
partition ownership ⇒ no per-event committed checks, O(batch) prefix commits
against short per-partition logs) plus overlapping shard batches.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.bus import PartitionedEventStore
from repro.core import Triggerflow, make_trigger, termination_event

from benchmarks.load_test import bench_noop

SHARD_COUNTS = (1, 2, 4, 8)


def bench_sharded_noop(
    n_events: int = 100_000,
    shards: int = 4,
    partitions: int = 16,
    subjects: int = 64,
    batch_size: int = 4096,
) -> Dict:
    store = PartitionedEventStore(partitions)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.create_workflow("load")
    for s in range(subjects):
        tf.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    events = [termination_event(f"e{i % subjects}", i) for i in range(n_events)]
    store.publish_batch("load", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("load", shards)
    while store.lag("load") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    processed = tf.pool.total_events_processed("load")
    assert processed >= n_events, (processed, n_events)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions}


def run(reps: int = 3, n_events: int = 100_000) -> List[Dict]:
    # Interleave scenarios across repetitions and keep the best events/s per
    # scenario: single-run numbers on small shared machines swing ±25% from
    # CPU steal, which would drown the architectural deltas being measured.
    best: Dict = {"baseline": 0.0}
    best.update({s: 0.0 for s in SHARD_COUNTS})
    for _ in range(reps):
        best["baseline"] = max(best["baseline"],
                               bench_noop(n_events)["events_per_s"])
        for shards in SHARD_COUNTS:
            r = bench_sharded_noop(n_events=n_events, shards=shards)
            best[shards] = max(best[shards], r["events_per_s"])

    rows = [{
        "name": "sharded_load.baseline_single_worker",
        "us_per_call": 1e6 / best["baseline"],
        "derived": f"{best['baseline']:.0f} events/s (bench_noop, best of {reps})",
    }]
    for shards in SHARD_COUNTS:
        speedup = best[shards] / best["baseline"]
        rows.append({
            "name": f"sharded_load.noop_{shards}shard",
            "us_per_call": 1e6 / best[shards],
            "derived": f"{best[shards]:.0f} events/s "
                       f"({speedup:.2f}x vs single worker)",
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
