"""repro.bus reproduction: events/s vs worker-shard count.

The Table 1 scenario (noop triggers, §6.1) run on the sharded dataplane:
events are keyed over ``subjects`` distinct trigger subjects, routed onto a
partitioned event bus, and drained by {1, 2, 4, 8} ShardWorker shards running
on their own threads.  The single-worker ``load_test.bench_noop`` figure on
the same machine (action plane on, like the shards) is reported as the
baseline; multi-shard rows also report scaling vs the 1-shard row — the
apples-to-apples number (same subjects/partitions/store), and the one the
store's lock granularity governs.

Shard throughput comes from the consumer-group fast path (exclusive
partition ownership ⇒ no per-event committed checks, O(batch) prefix commits
against short per-partition logs) plus overlapping shard batches; on
GIL-bound boxes with few cores, thread shards cannot beat the interpreter's
serial ceiling, which is what the striped-vs-global-lock contention rows
(4 shards, batch 256) isolate: same workload, only the lock granularity
changes.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.bus import PartitionedEventStore
from repro.core import Triggerflow, make_trigger, termination_event

from benchmarks.load_test import bench_join, bench_noop

SHARD_COUNTS = (1, 2, 4, 8)


def bench_sharded_noop(
    n_events: int = 100_000,
    shards: int = 4,
    partitions: int = 16,
    subjects: int = 64,
    batch_size: int = 4096,
    striped: bool = True,
) -> Dict:
    """``striped=False`` serializes every partition behind one lock — the
    pre-striping store, kept as the contention baseline.  Small
    ``batch_size`` values raise the store-call rate and make the lock
    granularity visible."""
    store = PartitionedEventStore(partitions, striped=striped)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.create_workflow("load")
    for s in range(subjects):
        tf.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    events = [termination_event(f"e{i % subjects}", i) for i in range(n_events)]
    store.publish_batch("load", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("load", shards)
    while store.lag("load") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    processed = tf.pool.total_events_processed("load")
    assert processed >= n_events, (processed, n_events)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions}


def bench_sharded_join(
    n_triggers: int = 100,
    events_each: int = 1000,
    shards: int = 4,
    partitions: int = 16,
    batch_size: int = 4096,
    batch_plane: bool = True,
) -> Dict:
    """The Table-1 join workload on the sharded dataplane: proves the batch
    plane (grouped slices + vectorized triage) composes with partitioned
    shards — each shard triages its own partitions' share of the batch.
    ``batch_plane=False`` is the interpreter-on-shards control."""
    store = PartitionedEventStore(partitions)
    tf = Triggerflow(event_store=store, inline_functions=True,
                     commit_policy="every_batch")
    tf.pool.batch_size = batch_size
    tf.pool.keep_event_log = False
    tf.pool.batch_plane = batch_plane
    tf.create_workflow("join")
    for t in range(n_triggers):
        tf.add_trigger("join", make_trigger(
            f"j{t}",
            condition={"name": "counter", "expected": events_each,
                       "aggregate": False},
            action={"name": "noop"}, trigger_id=f"jt{t}", transient=False))
    n_events = n_triggers * events_each
    events = [termination_event(f"j{i % n_triggers}", i) for i in range(n_events)]
    store.publish_batch("join", events)

    t0 = time.perf_counter()
    tf.pool.start_shards("join", shards)
    while store.lag("join") > 0:
        time.sleep(0.0005)
    dt = time.perf_counter() - t0
    tf.shutdown()
    fired = tf.pool.total_fires("join")
    assert fired == n_triggers, (fired, n_triggers)
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "shards": shards, "partitions": partitions, "fired": fired}


def run(reps: int = 3, n_events: int = 100_000) -> List[Dict]:
    # Interleave scenarios across repetitions and keep the best events/s per
    # scenario: single-run numbers on small shared machines swing ±25% from
    # CPU steal, which would drown the architectural deltas being measured.
    best: Dict = {"baseline": 0.0, "contention_striped": 0.0,
                  "contention_coarse": 0.0}
    best.update({s: 0.0 for s in SHARD_COUNTS})
    for _ in range(reps):
        # baseline runs the same plane configuration as the shards (action
        # plane on), so shard-count rows measure scaling, not plane deltas
        best["baseline"] = max(
            best["baseline"],
            bench_noop(n_events, action_plane=True)["events_per_s"])
        for shards in SHARD_COUNTS:
            r = bench_sharded_noop(n_events=n_events, shards=shards)
            best[shards] = max(best[shards], r["events_per_s"])
        # store-lock contention A/B: 4 shards, small batches (high store-call
        # rate), striped per-partition locks vs the old global lock
        for key, striped in (("contention_striped", True),
                             ("contention_coarse", False)):
            r = bench_sharded_noop(n_events=n_events, shards=4,
                                   batch_size=256, striped=striped)
            best[key] = max(best[key], r["events_per_s"])

    rows = [{
        "name": "sharded_load.baseline_single_worker",
        "us_per_call": 1e6 / best["baseline"],
        "events_per_s": best["baseline"],
        "derived": f"{best['baseline']:.0f} events/s (bench_noop, best of {reps})",
    }]
    for shards in SHARD_COUNTS:
        speedup = best[shards] / best["baseline"]
        scaling = best[shards] / best[1]
        rows.append({
            "name": f"sharded_load.noop_{shards}shard",
            "us_per_call": 1e6 / best[shards],
            "events_per_s": best[shards],
            "derived": f"{best[shards]:.0f} events/s "
                       f"({speedup:.2f}x vs single worker, "
                       f"{scaling:.2f}x vs 1 shard)",
        })
    coarse = best["contention_coarse"]
    striped = best["contention_striped"]
    rows.append({
        "name": "sharded_load.noop_4shard_contention_coarse",
        "us_per_call": 1e6 / coarse,
        "events_per_s": coarse,
        "derived": f"{coarse:.0f} events/s (4 shards, batch 256, one global "
                   f"store lock)",
    })
    rows.append({
        "name": "sharded_load.noop_4shard_contention",
        "us_per_call": 1e6 / striped,
        "events_per_s": striped,
        "derived": f"{striped:.0f} events/s "
                   f"({striped / coarse:.2f}x vs global lock; 4 shards, "
                   f"batch 256, striped per-partition locks)",
    })
    # Batch plane × sharding composition: the same 4-shard deployment with
    # the interpreter vs the batch plane (the latter must not regress).
    join_interp = join_batch = 0.0
    for _ in range(reps):
        join_interp = max(join_interp,
                          bench_sharded_join(batch_plane=False)["events_per_s"])
        join_batch = max(join_batch,
                         bench_sharded_join(batch_plane=True)["events_per_s"])
    join_single = bench_join()["events_per_s"]
    rows.append({
        "name": "sharded_load.join_4shard_interpreter",
        "us_per_call": 1e6 / join_interp,
        "events_per_s": join_interp,
        "derived": f"{join_interp:.0f} events/s (per-event interpreter on "
                   f"4 shards)",
    })
    rows.append({
        "name": "sharded_load.join_4shard",
        "us_per_call": 1e6 / join_batch,
        "events_per_s": join_batch,
        "derived": f"{join_batch:.0f} events/s "
                   f"({join_batch / join_interp:.2f}x vs interpreter shards, "
                   f"{join_batch / join_single:.2f}x vs 1 batch-plane worker)",
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
