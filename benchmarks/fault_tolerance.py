"""Fig 13 reproduction: kill the TF-Worker mid-workflow; recovery from the
durable stores (trigger contexts + uncommitted event replay) finishes the
workflow correctly — vs a polling client that loses all state and must rerun
everything.

Workflow: geospatial-style 3-stage DAG — partition → map(compute×12) →
reduce — on FileEventStore/FileStateStore.  The worker process state is
evicted right after the map fan-out started (paper: "stopped at the 20th
second").
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

from repro.core import FileEventStore, FileStateStore, Triggerflow
from repro.core.dag import DAG, MapOperator, PythonOperator

TASK_S = 0.15
WIDTH = 12
EXECUTIONS = {"count": 0}


def _compute(x):
    EXECUTIONS["count"] += 1
    time.sleep(TASK_S)
    return x * x


def _build(tf: Triggerflow, wf: str) -> DAG:
    dag = DAG(wf)
    a = dag.add(PythonOperator("partition", lambda x: list(range(WIDTH))))
    b = dag.add(MapOperator("compute", _compute))
    c = dag.add(PythonOperator("reduce", lambda xs: sum(xs)))
    a >> b >> c
    dag.deploy(tf, wf)
    return dag


def run() -> List[Dict]:
    tmp = tempfile.mkdtemp(prefix="tf-ft-")
    es, ss = FileEventStore(tmp + "/events"), FileStateStore(tmp + "/state")
    tf = Triggerflow(event_store=es, state_store=ss)
    dag = _build(tf, "geo")
    EXECUTIONS["count"] = 0
    expected = sum(i * i for i in range(WIDTH))

    t0 = time.perf_counter()
    tf.init_workflow("geo")
    w = tf.worker("geo")
    # run until the map fan-out has started, then crash the worker
    while tf.backend.invocations < 1 + WIDTH // 2:
        w.run_once()
        time.sleep(0.01)
    tf.evict_worker("geo")  # ← the intentional failure
    crash_t = time.perf_counter() - t0

    # restart: new service process over the same durable stores
    es2, ss2 = FileEventStore(tmp + "/events"), FileStateStore(tmp + "/state")
    tf2 = Triggerflow(event_store=es2, state_store=ss2)
    tf2.backend.register("geo:partition", lambda x: list(range(WIDTH)))
    tf2.backend.register("geo:compute", _compute)
    tf2.backend.register("geo:reduce", lambda xs: sum(xs))
    res = tf2.run_until_complete("geo", timeout=60)
    total_t = time.perf_counter() - t0
    assert res["status"] == "succeeded" and res["result"] == expected, res
    reruns = EXECUTIONS["count"] - WIDTH
    tf.shutdown()
    tf2.shutdown()

    # baseline: polling client loses everything → full re-execution
    baseline_reruns = WIDTH  # by construction (client restarts from scratch)
    return [{
        "name": "fault_tolerance.kill_recover",
        "us_per_call": total_t / WIDTH * 1e6,
        "derived": (f"crash_at={crash_t:.2f}s recovered result={res['result']} "
                    f"task_reruns={reruns}/{WIDTH} "
                    f"(lithops-style baseline reruns {baseline_reruns}/{WIDTH}) "
                    f"total={total_t:.2f}s"),
    }]
