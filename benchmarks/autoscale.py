"""Fig 8 on the sharded runtimes: pool-agnostic scale 0→N→0.

The original ``benchmarks/autoscaling.py`` reproduces Fig 8 in *classic*
mode (one TF-Worker per workflow over the unpartitioned in-memory store).
This module reproduces it on the **sharded** runtimes through the
``ScalablePool`` protocol, with the identical driver for both substrates:

* ``--mode=thread`` — ``ShardedWorkerPool`` shards (threads, in-memory bus),
* ``--mode=process`` — ``ProcessShardPool`` shard *processes* over the
  durable file bus: the paper's KEDA/Knative container-per-worker shape.
  Scale-to-zero here means zero OS processes, and scale-up re-forks them.

Workload: a burst is published into a drained, zero-shard deployment; the
``KedaAutoscaler`` scales 0→N (lag-proportional), the shards drain the
stream, idle out within the grace period, and are reaped back to 0; a second
burst then re-scales from zero.  The recorded ``timeline`` of
``(t, active_shards, total_lag)`` samples is the figure's data; the derived
fields pin the headline numbers (peak shards, seconds from drain to zero).

``idle_stats`` measures what an *idle* autoscaler tick costs on the file
bus: stat calls per ``lag()`` poll at two partition widths — the
publish-notify gate keeps it at exactly one, independent of partitions.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time
from typing import Dict, List, Optional

from repro.bus import FilePartitionedEventStore, PartitionedEventStore, ProcessShardPool
from repro.core import KedaAutoscaler, Triggerflow, make_trigger, termination_event


def _deployment(mode: str, root: Optional[str], subjects: int,
                partitions: int, batch_size: int) -> Triggerflow:
    if mode == "thread":
        tf = Triggerflow(event_store=PartitionedEventStore(partitions),
                         inline_functions=True, commit_policy="every_batch")
        tf.pool.batch_size = batch_size
        tf.pool.keep_event_log = False
        tf.create_workflow("load")
    else:
        pool = ProcessShardPool(root, num_partitions=partitions,
                                batch_size=batch_size, fsync=False)
        pool.create_workflow("load")
        tf = Triggerflow(pool=pool)
    for s in range(subjects):
        tf.add_trigger("load", make_trigger(
            f"e{s}", condition={"name": "true"}, action={"name": "noop"},
            trigger_id=f"noop{s}", transient=False))
    return tf


def _wait(cond, timeout: float, msg: str, poll: float = 0.01) -> float:
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() > deadline:
            raise TimeoutError(msg)
        time.sleep(poll)
    return time.monotonic()


def bench_fig8(
    mode: str = "thread",
    n_events: int = 60_000,
    subjects: int = 32,
    partitions: int = 8,
    batch_size: int = 2048,
    events_per_shard: int = 2_000,
    max_shards: int = 4,
    grace: float = 0.25,
    poll: float = 0.02,
    root: Optional[str] = None,
) -> Dict:
    """One full Fig-8 cycle pair: burst → 0→N→0, second burst → re-scale.

    Returns the benchmark row, including the sampled timeline.  Asserts the
    two headline claims: lag-proportional scale-up reached ≥ 2 shards, and
    live shards decayed to zero within ~one grace period of the drain."""
    own_root = mode == "process" and root is None
    if own_root:
        root = tempfile.mkdtemp(prefix="tf-autoscale-")
    tf = _deployment(mode, root, subjects, partitions, batch_size)
    store = tf.event_store
    scaler = KedaAutoscaler(tf, poll_interval=poll, grace_period=grace,
                            events_per_shard=events_per_shard,
                            max_shards_per_workflow=max_shards)
    second = n_events // 2
    t0 = time.monotonic()
    scaler.start()
    try:
        zero_after: List[float] = []
        for phase, count, base in (("first", n_events, 0),
                                   ("second", second, n_events)):
            store.publish_batch("load", [
                termination_event(f"e{i % subjects}", base + i)
                for i in range(count)])
            t_drain = _wait(lambda: store.lag("load") == 0, 120,
                            f"{phase} burst did not drain")
            t_zero = _wait(lambda: scaler.active_workers == 0, 60,
                           f"no scale-to-zero after the {phase} burst")
            zero_after.append(t_zero - t_drain)
        # let the scaler's own ticks retire every departed shard before the
        # run closes — calling reap() from here would steal the departures
        # from the scaler's scale_downs accounting.  (scale_ups can still
        # legitimately exceed scale_downs by a hair: an idle thread-shard
        # *task* rescheduled by a later tick before reap() saw it counts as
        # a fresh scale-up but departs only once.)
        pool = tf.pool
        _wait(lambda: pool.shard_count("load") == 0, 10,
              "not every departed shard was reaped")
        wall = time.monotonic() - t0
    finally:
        scaler.stop()
        tf.shutdown()
        if own_root:
            shutil.rmtree(root, ignore_errors=True)
    peak = max(w for _, w, _ in scaler.timeline)
    zeros = sum(1 for _, w, _ in scaler.timeline if w == 0)
    total = n_events + second
    assert peak >= 2, f"lag-proportional scale-up never reached 2 (peak={peak})"
    # "within one grace period of drain", plus control-loop ticks and a
    # constant for process teardown under CPU steal (the derived row carries
    # the exact measurement; this assert only bounds gross regressions)
    slack = grace + 6 * poll + 0.6
    assert max(zero_after) <= slack, \
        f"scale-to-zero took {max(zero_after):.2f}s (grace={grace}s)"
    unit = "shard processes" if mode == "process" else "thread shards"
    return {
        "name": f"autoscale.fig8_{mode}",
        "us_per_call": wall / total * 1e6,
        "events_per_s": total / wall,
        "derived": (
            f"0->{peak}->0 {unit} twice over {total} events "
            f"(lag-proportional, cap {max_shards}); drain->zero in "
            f"{zero_after[0]:.2f}s/{zero_after[1]:.2f}s (grace {grace}s); "
            f"scale_ups={scaler.scale_ups} scale_downs={scaler.scale_downs} "
            f"restarts={scaler.restarts} zero_samples={zeros} "
            f"wall={wall:.1f}s"),
        "timeline": [(round(t, 3), w, lag) for t, w, lag in scaler.timeline],
    }


def bench_idle_tick_stats(polls: int = 200,
                          widths: tuple = (8, 64)) -> Dict:
    """Stat calls per idle autoscaler lag poll on the file bus, at two
    partition widths.  The publish-notify gate makes the answer 1 regardless
    of width — without it every poll pays O(partitions) probes."""
    per_width: Dict[int, float] = {}
    real_getsize = os.path.getsize
    for partitions in widths:
        root = tempfile.mkdtemp(prefix="tf-idlestat-")
        try:
            store = FilePartitionedEventStore(root, partitions, fsync=False)
            store.create_stream("load")
            evs = [termination_event(f"e{i}", i) for i in range(64)]
            store.publish_batch("load", evs)
            store.commit("load", [e.id for e in evs])
            assert store.lag("load") == 0  # observe + cache the drained state
            calls = [0]

            def counting(path, _c=calls, _r=real_getsize):
                _c[0] += 1
                return _r(path)

            os.path.getsize = counting
            try:
                for _ in range(polls):
                    assert store.lag("load") == 0
            finally:
                os.path.getsize = real_getsize
            per_width[partitions] = calls[0] / polls
        finally:
            shutil.rmtree(root, ignore_errors=True)
    flat = max(per_width.values())
    assert flat <= 1.5, f"idle lag poll is not O(1): {per_width}"
    detail = ", ".join(f"{v:.2f} @ {k} partitions"
                       for k, v in sorted(per_width.items()))
    return {
        "name": "autoscale.idle_tick_stats",
        "us_per_call": 0.0,
        "derived": (f"stat calls per idle lag() poll: {detail} — "
                    f"publish-notify-gated, flat in partition count"),
    }


def run(mode: str = "all") -> List[Dict]:
    rows: List[Dict] = []
    if mode in ("all", "thread"):
        rows.append(bench_fig8("thread"))
    if mode in ("all", "process"):
        rows.append(bench_fig8(
            "process", n_events=20_000, subjects=16, partitions=4,
            batch_size=1024, events_per_shard=2_000, max_shards=2,
            grace=0.5, poll=0.05))
    rows.append(bench_idle_tick_stats())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("thread", "process", "all"),
                    default="all")
    args = ap.parse_args()
    for row in run(mode=args.mode):
        print(f"{row['name']},{row['us_per_call']:.2f},\"{row['derived']}\"")
