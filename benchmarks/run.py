"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per measurement) and writes
the raw rows to results/benchmarks.json.

    PYTHONPATH=src python -m benchmarks.run [--only load_test,overhead]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

SUITES = [
    ("load_test", "Table 1 — events/s per worker"),
    ("sharded_load", "repro.bus — events/s vs worker-shard count"),
    ("overhead", "Fig 9/10 — seq + parallel DAG overhead vs baselines"),
    ("event_sourcing", "Fig 11/12 — workflow-as-code replay overhead"),
    ("autoscaling", "Fig 8 — KEDA-style scale up/down to zero"),
    ("autoscale", "Fig 8 on the sharded runtimes — 0→N→0 thread + process shards"),
    ("fault_tolerance", "Fig 13 — worker kill + recovery"),
    ("montage", "Fig 14-16 — nested state machine, scale-to-zero"),
    ("fedlearn_bench", "Fig 17 — federated learning rounds"),
    ("roofline", "§Roofline — per (arch × shape) dry-run terms"),
    ("obs", "Observability — metrics/trace plane overhead on the noop action plane"),
    ("policy", "Failure policy — idle retry-policy overhead on the noop action plane"),
    ("replication", "Host-loss domain — segment-transport overhead on the file bus"),
    ("codec", "Event codec — v1 JSON lines vs TFB1 columnar frames"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else [s for s, _ in SUITES]

    all_rows = []
    failures = 0
    print("name,us_per_call,derived")
    for suite, desc in SUITES:
        if suite not in chosen:
            continue
        mod = __import__(f"benchmarks.{suite}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{suite}.FAILED,0,see stderr")
            failures += 1
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.2f},\"{r['derived']}\"")
            row = dict(r)
            if "timeline" in row:
                # keep the Fig-8 data, bounded (the committed artifact)
                row["timeline"] = [list(t) for t in row["timeline"][-200:]]
            all_rows.append(row)
        sys.stdout.flush()
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "benchmarks.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    if args.only and os.path.exists(out):
        # partial rerun: replace only the rerun suites' rows, keep every
        # other committed row (a bare --only must not clobber the file)
        with open(out) as f:
            kept = [r for r in json.load(f)
                    if r.get("name", "").split(".", 1)[0] not in chosen]
        all_rows = kept + all_rows
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
