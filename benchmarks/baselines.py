"""Non-trigger orchestration baselines (the paper compares against cloud
services we cannot call offline; these are their architectural stand-ins).

* ``DirectOrchestrator``  — Composer-style centralized always-on driver: calls
  the thread pool directly and blocks on futures.  The overhead floor.
* ``PollingOrchestrator`` — original-Lithops-style client: fires tasks, then
  polls a result store at a fixed interval (the S3-polling pattern §1).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List


class DirectOrchestrator:
    def __init__(self, max_workers: int = 64):
        self.pool = ThreadPoolExecutor(max_workers=max_workers)

    def run_sequence(self, fn: Callable, n: int, x: Any = 0) -> Any:
        for _ in range(n):
            x = self.pool.submit(fn, x).result()
        return x

    def run_parallel(self, fn: Callable, items: List[Any]) -> List[Any]:
        return [f.result() for f in [self.pool.submit(fn, it) for it in items]]

    def shutdown(self):
        self.pool.shutdown(wait=False)


class PollingOrchestrator:
    def __init__(self, max_workers: int = 64, poll_interval: float = 0.01):
        self.pool = ThreadPoolExecutor(max_workers=max_workers)
        self.poll_interval = poll_interval
        self.results: Dict[str, Any] = {}
        self.polls = 0
        self._lock = threading.Lock()

    def _run(self, key: str, fn: Callable, arg: Any) -> None:
        out = fn(arg)
        with self._lock:
            self.results[key] = out

    def _wait(self, keys: List[str]) -> List[Any]:
        while True:
            with self._lock:
                if all(k in self.results for k in keys):
                    return [self.results[k] for k in keys]
            self.polls += 1
            time.sleep(self.poll_interval)

    def run_sequence(self, fn: Callable, n: int, x: Any = 0) -> Any:
        for i in range(n):
            key = f"s{i}"
            self.pool.submit(self._run, key, fn, x)
            x = self._wait([key])[0]
        return x

    def run_parallel(self, fn: Callable, items: List[Any]) -> List[Any]:
        keys = []
        for i, it in enumerate(items):
            key = f"p{i}"
            keys.append(key)
            self.pool.submit(self._run, key, fn, it)
        return self._wait(keys)

    def shutdown(self):
        self.pool.shutdown(wait=False)
