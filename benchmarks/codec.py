"""Event-codec benchmark: v1 JSON lines vs TFB1 columnar frames.

The per-event decode cost is the durable bus consumer's floor — every
consume/refresh/replay pays it before any trigger logic runs.  Three decode
rows, all over the same stamped event stream in store-shaped batches:

* codec.decode_json    — the legacy wire format: one JSON event per line,
                         ``CloudEvent.from_json`` per event.
* codec.decode_frame   — TFB1 columnar frames decoded *and* materialized to
                         per-event CloudEvents (the live ``sync`` path).
                         Gated in CI at >= 2x of decode_json on the best
                         *paired* ratio (``scripts/perf_gate.py``).
* codec.decode_columns — frames decoded to :class:`EventColumns` only (the
                         ``VectorJoinPlane.triage`` ingest path: ids /
                         subjects / types / results, no event objects).

Plus the matching encode pair (one ``to_json`` per event vs one frame per
batch) and the wire size per event in the decode_frame row's derived text.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import termination_event
from repro.core import codec as _codec
from repro.core.events import stamp_publish_time


def _batches(n_events: int, batch: int, subjects: int):
    evs = [termination_event("s%d" % (i % subjects), i)
           for i in range(n_events)]
    out = []
    for i in range(0, n_events, batch):
        b = evs[i:i + batch]
        stamp_publish_time(b)  # published batches share one time stamp
        out.append(b)
    return out


def bench_codec(n_events: int = 200_000, batch: int = 512,
                subjects: int = 32) -> Dict[str, float]:
    """One paired measurement: every rate comes from the same event stream
    in the same process, back to back."""
    batches = _batches(n_events, batch, subjects)
    json_lines: List[List[str]] = []
    frames: List[bytes] = []

    t0 = time.perf_counter()
    for b in batches:
        json_lines.append([e.to_json() for e in b])
    t_enc_json = time.perf_counter() - t0

    t0 = time.perf_counter()
    for b in batches:
        frames.append(_codec.encode_frame_payload(b))
    t_enc_frame = time.perf_counter() - t0

    from_json = _codec.event_from_json
    t0 = time.perf_counter()
    for lines in json_lines:
        for line in lines:
            from_json(line)
    t_dec_json = time.perf_counter() - t0

    decode_frame = _codec.decode_frame_payload
    t0 = time.perf_counter()
    for f in frames:
        decode_frame(f).events()
    t_dec_frame = time.perf_counter() - t0

    t0 = time.perf_counter()
    for f in frames:
        cols = decode_frame(f)
        cols.results()  # the triage feed: ids/subjects/types + result column
    t_dec_cols = time.perf_counter() - t0

    json_bytes = sum(len(line) + 1 for lines in json_lines for line in lines)
    frame_bytes = sum(len(_codec.encode_record(f)) for f in frames)
    return {
        "events": n_events,
        "enc_json": n_events / t_enc_json,
        "enc_frame": n_events / t_enc_frame,
        "dec_json": n_events / t_dec_json,
        "dec_frame": n_events / t_dec_frame,
        "dec_cols": n_events / t_dec_cols,
        "json_bytes_per_event": json_bytes / n_events,
        "frame_bytes_per_event": frame_bytes / n_events,
    }


def run(reps: int = 3) -> List[Dict]:
    best: Dict[str, float] = {}
    ratio = {"dec_frame": 0.0, "dec_cols": 0.0, "enc_frame": 0.0}
    bytes_info = {}
    for _ in range(reps):
        m = bench_codec()
        for k in ("enc_json", "enc_frame", "dec_json", "dec_frame",
                  "dec_cols"):
            best[k] = max(best.get(k, 0.0), m[k])
        # best-*paired* ratios: both sides of each ratio come from the same
        # in-process run, so machine drift cancels
        ratio["dec_frame"] = max(ratio["dec_frame"],
                                 m["dec_frame"] / m["dec_json"])
        ratio["dec_cols"] = max(ratio["dec_cols"],
                                m["dec_cols"] / m["dec_json"])
        ratio["enc_frame"] = max(ratio["enc_frame"],
                                 m["enc_frame"] / m["enc_json"])
        bytes_info = {"json": m["json_bytes_per_event"],
                      "frame": m["frame_bytes_per_event"]}

    def row(name: str, key: str, note: str) -> Dict:
        eps = best[key]
        return {"name": name, "us_per_call": 1e6 / eps, "events_per_s": eps,
                "derived": f"{eps:.0f} events/s ({note}, best of {reps})"}

    frame_eps = best["dec_frame"]
    cols_eps = best["dec_cols"]
    return [
        row("codec.decode_json", "dec_json",
            "v1 JSON lines, from_json per event"),
        {"name": "codec.decode_frame", "us_per_call": 1e6 / frame_eps,
         "events_per_s": frame_eps,
         "derived": f"{frame_eps:.0f} events/s (TFB1 frames -> CloudEvents, "
                    f"{ratio['dec_frame']:.2f}x of v1 decode paired, "
                    f"{bytes_info['frame']:.0f} vs {bytes_info['json']:.0f} "
                    f"bytes/event, best of {reps})"},
        {"name": "codec.decode_columns", "us_per_call": 1e6 / cols_eps,
         "events_per_s": cols_eps,
         "derived": f"{cols_eps:.0f} events/s (TFB1 frames -> EventColumns "
                    f"only, {ratio['dec_cols']:.2f}x of v1 decode paired, "
                    f"best of {reps})"},
        row("codec.encode_json", "enc_json",
            "v1 JSON lines, to_json per event"),
        {"name": "codec.encode_frame", "us_per_call": 1e6 / best["enc_frame"],
         "events_per_s": best["enc_frame"],
         "derived": f"{best['enc_frame']:.0f} events/s (one columnar frame "
                    f"per batch, {ratio['enc_frame']:.2f}x of v1 encode "
                    f"paired, best of {reps})"},
    ]
