"""Replication overhead: the durable file bus with the segment transport on.

Three rows, all the same store-level noop workload — publish / consume /
commit over ``FilePartitionedEventStore`` (``fsync=False``, so segment
appends rather than disk flushes dominate and the transport's cost is
maximally visible):

* replication.noop_off  — plain file bus (the committed baseline shape).
* replication.noop_on   — every segment mutation shipped to a live
                          ``ReplicaServer`` through the default *pipelined*
                          client (merged frames, scatter-gather sends, acks
                          drained in the background), including the final
                          ``drain_replication`` so unacked frames cannot
                          flatter the number.  Gated in CI at >= 0.85x of
                          replication-off on the best *paired* off/on ratio
                          (``scripts/perf_gate.py``).
* replication.noop_sync — the semi-sync client (each append blocks on its
                          ack): the price of a hard zero-lag recovery
                          point, reported for the table but not gated.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Dict, List

from repro.bus import ReplicaServer
from repro.bus.partitioned import FilePartitionedEventStore
from repro.core import termination_event


def bench_replicated_bus(n_events: int = 50_000, partitions: int = 4,
                         subjects: int = 32, batch: int = 1024,
                         replicate: bool = False,
                         sync: bool = False) -> Dict:
    """Store-level publish/consume/commit loop; with ``replicate`` every
    mutation also ships to a live replica and the timed window includes the
    final pipeline drain (replica fully caught up, byte for byte)."""
    root = tempfile.mkdtemp(prefix="tf-repl-bench-")
    server = None
    store = None
    try:
        kw = {}
        if replicate:
            server = ReplicaServer(os.path.join(root, "replica"))
            kw = {"replicate_to": server.address, "replicate_sync": sync}
        store = FilePartitionedEventStore(
            os.path.join(root, "bus"), partitions, fsync=False, **kw)
        wf = "bench"
        events = [termination_event("s%d" % (i % subjects), i)
                  for i in range(n_events)]
        t0 = time.perf_counter()
        for i in range(0, n_events, batch):
            store.publish_batch(wf, events[i:i + batch])
        done = 0
        while done < n_events:
            got = store.consume(wf, batch)
            if not got:
                break
            store.commit(wf, [e.id for e in got])
            done += len(got)
        assert store.drain_replication(30.0), "replication never drained"
        dt = time.perf_counter() - t0
        assert done == n_events and store.lag(wf) == 0
        if replicate:
            assert store.replication_stats()["lag_bytes"] == 0
        return {"events": n_events, "seconds": dt,
                "events_per_s": n_events / dt}
    finally:
        if store is not None and store._rep is not None:
            store._rep.close()
        if server is not None:
            server.close()
        shutil.rmtree(root, ignore_errors=True)


def run(reps: int = 3) -> List[Dict]:
    # Interleaved, and the quoted overhead ratios are best-*paired* (each
    # variant against the replication-off run measured right next to it):
    # pairing cancels machine-state drift that best-of-each-side does not.
    best = {"off": 0.0, "on": 0.0, "sync": 0.0}
    ratio = {"off": 1.0, "on": 0.0, "sync": 0.0}
    for _ in range(reps):
        off = bench_replicated_bus()["events_per_s"]
        on = bench_replicated_bus(replicate=True)["events_per_s"]
        syn = bench_replicated_bus(replicate=True, sync=True)["events_per_s"]
        best["off"] = max(best["off"], off)
        best["on"] = max(best["on"], on)
        best["sync"] = max(best["sync"], syn)
        ratio["on"] = max(ratio["on"], on / off)
        ratio["sync"] = max(ratio["sync"], syn / off)

    def row(name: str, key: str, note: str) -> Dict:
        eps = best[key]
        return {"name": name, "us_per_call": 1e6 / eps, "events_per_s": eps,
                "derived": f"{eps:.0f} events/s ({note}, "
                           f"{ratio[key]:.2f}x of replication-off paired, "
                           f"best of {reps})"}

    return [
        {"name": "replication.noop_off", "us_per_call": 1e6 / best["off"],
         "events_per_s": best["off"],
         "derived": f"{best['off']:.0f} events/s "
                    f"(file bus, replication off, best of {reps})"},
        row("replication.noop_on", "on", "pipelined transport + drain"),
        row("replication.noop_sync", "sync", "semi-sync: per-append ack"),
    ]
