"""Fig 9 / Fig 10 reproduction: orchestration overhead for sequential and
parallel (fork-join) workflows, Triggerflow DAG engine vs the Direct and
Polling baselines.

overhead(g) = exec_time(g) − Σ exec_time(f_i)   (paper §6.3)

Task durations are scaled down 20× vs the paper (0.15 s instead of 3 s
sequential; 0.5 s instead of 20 s parallel) so the suite stays minutes-long;
overheads are absolute and comparable across systems.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import Triggerflow
from repro.core.dag import DAG, MapOperator, PythonOperator

from .baselines import DirectOrchestrator, PollingOrchestrator

SEQ_TASK_S = 0.15
PAR_TASK_S = 0.5
SEQ_NS = (5, 10, 20, 40, 80)
PAR_NS = (5, 20, 80, 320)


def _sleep_task(x):
    time.sleep(SEQ_TASK_S)
    return (x or 0) + 1


def _par_task(x):
    time.sleep(PAR_TASK_S)
    return x


def tf_sequence(n: int) -> float:
    tf = Triggerflow()  # threaded functions, worker driven inline
    dag = DAG(f"seq{n}")
    prev = None
    for i in range(n):
        op = dag.add(PythonOperator(f"t{i}", _sleep_task))
        if prev is not None:
            prev >> op
        prev = op
    dag.deploy(tf, f"seq{n}")
    t0 = time.perf_counter()
    res = dag.run(tf, f"seq{n}", timeout=n * SEQ_TASK_S * 4 + 30)
    dt = time.perf_counter() - t0
    assert res["status"] == "succeeded", res
    tf.shutdown()
    return dt - n * SEQ_TASK_S


def tf_parallel(n: int) -> float:
    tf = Triggerflow(backend=None)
    tf.backend._max_workers = max(n + 8, 64)
    dag = DAG(f"par{n}")
    gen = dag.add(PythonOperator("gen", lambda x: list(range(n))))
    fan = dag.add(MapOperator("fan", _par_task))
    red = dag.add(PythonOperator("red", lambda xs: len(xs)))
    gen >> fan >> red
    dag.deploy(tf, f"par{n}")
    t0 = time.perf_counter()
    res = dag.run(tf, f"par{n}", timeout=PAR_TASK_S * 8 + 60)
    dt = time.perf_counter() - t0
    assert res["status"] == "succeeded" and res["result"] == n, res
    tf.shutdown()
    return dt - PAR_TASK_S


def baseline_sequence(orch, n: int) -> float:
    t0 = time.perf_counter()
    orch.run_sequence(_sleep_task, n)
    dt = time.perf_counter() - t0
    orch.shutdown()
    return dt - n * SEQ_TASK_S


def baseline_parallel(orch, n: int) -> float:
    t0 = time.perf_counter()
    out = orch.run_parallel(_par_task, list(range(n)))
    dt = time.perf_counter() - t0
    assert len(out) == n
    orch.shutdown()
    return dt - PAR_TASK_S


def run() -> List[Dict]:
    rows = []
    for n in SEQ_NS:
        o_tf = tf_sequence(n)
        o_direct = baseline_sequence(DirectOrchestrator(), n)
        o_poll = baseline_sequence(PollingOrchestrator(), n)
        rows.append({
            "name": f"overhead.seq.n{n}",
            "us_per_call": o_tf / n * 1e6,
            "derived": f"tf={o_tf:.3f}s direct={o_direct:.3f}s "
                       f"poll={o_poll:.3f}s (n={n})",
        })
    for n in PAR_NS:
        o_tf = tf_parallel(n)
        o_direct = baseline_parallel(DirectOrchestrator(max_workers=n + 8), n)
        o_poll = baseline_parallel(PollingOrchestrator(max_workers=n + 8), n)
        rows.append({
            "name": f"overhead.par.n{n}",
            "us_per_call": o_tf / n * 1e6,
            "derived": f"tf={o_tf:.3f}s direct={o_direct:.3f}s "
                       f"poll={o_poll:.3f}s (n={n})",
        })
    return rows
