"""Fig 11 / Fig 12 reproduction: Workflow-as-Code + event sourcing overhead —
native scheduler (replay inside the TF-Worker action) vs external scheduler
(Lithops/ADF-style re-invoked cloud function re-reading the event store),
for sequences and for a single parallel map.

Derived fields record replays and store round-trips: the paper's n(n+1)/2 vs
n request asymmetry is directly visible in ``store_requests``.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import Triggerflow
from repro.core.workflow_as_code import WorkflowAsCode

from .baselines import PollingOrchestrator

TASK_S = 0.1
SEQ_NS = (5, 10, 20)
PAR_N = 40


def _task(x):
    time.sleep(TASK_S)
    return (x or 0) + 1


def wac_sequence(n: int, scheduler: str) -> Dict:
    tf = Triggerflow()
    tf.backend.register("task", _task)

    def orch(ex):
        v = 0
        for _ in range(n):
            v = ex.call_async("task", v).result()
        return v

    wac = WorkflowAsCode(tf, f"wac-seq{n}-{scheduler}", orch, scheduler=scheduler)
    wac.deploy()
    t0 = time.perf_counter()
    res = wac.run(timeout=n * TASK_S * 6 + 30)
    dt = time.perf_counter() - t0
    assert res["result"] == n, res
    tf.shutdown()
    return {"overhead": dt - n * TASK_S, "replays": wac.replays,
            "store_requests": wac.store_requests}


def wac_parallel(n: int, scheduler: str) -> Dict:
    tf = Triggerflow()
    tf.backend.register("task", _task)

    def orch(ex):
        return sum(ex.map("task", list(range(n))).result())

    wac = WorkflowAsCode(tf, f"wac-par{n}-{scheduler}", orch, scheduler=scheduler)
    wac.deploy()
    t0 = time.perf_counter()
    res = wac.run(timeout=TASK_S * 10 + 30)
    dt = time.perf_counter() - t0
    assert res["result"] == n * (n + 1) // 2, res
    tf.shutdown()
    return {"overhead": dt - TASK_S, "replays": wac.replays,
            "store_requests": wac.store_requests}


def run() -> List[Dict]:
    rows = []
    for n in SEQ_NS:
        nat = wac_sequence(n, "native")
        ext = wac_sequence(n, "external")
        poll = PollingOrchestrator()
        t0 = time.perf_counter()
        poll.run_sequence(_task, n)
        p_ovh = time.perf_counter() - t0 - n * TASK_S
        poll.shutdown()
        rows.append({
            "name": f"event_sourcing.seq.n{n}",
            "us_per_call": nat["overhead"] / n * 1e6,
            "derived": (f"native={nat['overhead']:.3f}s (replays={nat['replays']}) "
                        f"external={ext['overhead']:.3f}s "
                        f"(store_reqs={ext['store_requests']}) "
                        f"lithops_poll={p_ovh:.3f}s"),
        })
    nat = wac_parallel(PAR_N, "native")
    ext = wac_parallel(PAR_N, "external")
    poll = PollingOrchestrator(max_workers=PAR_N + 8)
    t0 = time.perf_counter()
    poll.run_parallel(_task, list(range(PAR_N)))
    p_ovh = time.perf_counter() - t0 - TASK_S
    poll.shutdown()
    rows.append({
        "name": f"event_sourcing.par.n{PAR_N}",
        "us_per_call": nat["overhead"] / PAR_N * 1e6,
        "derived": (f"native={nat['overhead']:.3f}s external={ext['overhead']:.3f}s "
                    f"(replays nat/ext={nat['replays']}/{ext['replays']}) "
                    f"lithops_poll={p_ovh:.3f}s"),
    })
    return rows
