"""Observability overhead: the Table-1 noop action-plane workload with the
metrics plane and the trace plane switched on.

Four rows, all through the real TF-Worker on the action plane (the fastest
committed path — ``load_test.noop_action_plane`` — so any per-batch cost the
planes add is maximally visible):

* metrics_off    — planes disabled (the committed baseline configuration).
* metrics_on     — the default: per-stage histograms, one ``observe_batch``
                   per (trigger, slice) / consumed batch.  Gated in CI at
                   >= 0.9x of metrics_off (``scripts/perf_gate.py``).
* trace_sampled  — metrics + tracing at the default 10% root sampling.
* trace_full     — metrics + every fire spanned (sample=1.0).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import Triggerflow, make_trigger, termination_event
from repro.obs.trace import Tracer


def bench_obs_noop(n_events: int = 100_000, metrics: bool = True,
                   trace: float = 0.0) -> Dict:
    """``load_test.bench_noop(action_plane=True)`` with the observability
    planes toggled.  ``trace`` is the root sampling rate (0.0 = off)."""
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("load")
    tf.add_trigger("load", make_trigger(
        "e", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="noop", transient=False))
    events = [termination_event("e", i) for i in range(n_events)]
    tf.event_store.publish_batch("load", events)
    w = tf.worker("load")
    w.keep_event_log = False
    w.action_plane = True
    if not metrics:
        w._metrics = None
    if trace > 0.0:
        w._tracer = Tracer(sample=trace)
    t0 = time.perf_counter()
    done = 0
    while done < n_events:
        done += w.run_once(4096)
    dt = time.perf_counter() - t0
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt}


def run(reps: int = 3) -> List[Dict]:
    # Interleaved best-of (same rationale as load_test.run): the variants
    # being compared differ by a few percent, far below single-run noise on
    # shared machines.
    best = {"off": 0.0, "on": 0.0, "sampled": 0.0, "full": 0.0}
    for _ in range(reps):
        best["off"] = max(best["off"],
                          bench_obs_noop(metrics=False)["events_per_s"])
        best["on"] = max(best["on"],
                         bench_obs_noop(metrics=True)["events_per_s"])
        best["sampled"] = max(
            best["sampled"],
            bench_obs_noop(metrics=True, trace=0.1)["events_per_s"])
        best["full"] = max(
            best["full"],
            bench_obs_noop(metrics=True, trace=1.0)["events_per_s"])

    def row(name: str, key: str, note: str) -> Dict:
        eps = best[key]
        return {"name": name, "us_per_call": 1e6 / eps, "events_per_s": eps,
                "derived": f"{eps:.0f} events/s ({note}, "
                           f"{eps / best['off']:.2f}x of metrics-off, "
                           f"best of {reps})"}

    return [
        {"name": "obs.noop_metrics_off", "us_per_call": 1e6 / best["off"],
         "events_per_s": best["off"],
         "derived": f"{best['off']:.0f} events/s "
                    f"(planes off, best of {reps})"},
        row("obs.noop_metrics_on", "on", "metrics plane"),
        row("obs.noop_trace_sampled", "sampled", "metrics + 10% tracing"),
        row("obs.noop_trace_full", "full", "metrics + full tracing"),
    ]
