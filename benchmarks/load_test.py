"""Table 1 reproduction: max events/second through one TF-Worker.

Scenarios (paper §6.1):
* noop — events match a persistent trigger with a true condition + noop
          action.  Measured twice through the real TF-Worker: once with the
          per-fire action loop (``action_plane=False`` — the "before": one
          condition + one action Python round-trip per event) and once on
          the action plane (fire-run condition + batched action — two
          Python calls per slice).
* join — 100 triggers with aggregation conditions joining 1000 events each
          (the parallel map fork-join shape).  Measured twice through the
          *real* TF-Worker: once on the legacy per-event interpreter
          (``batch_plane=False`` — the "before") and once on the batch plane
          (grouped slices + vectorized ``event_join`` triage — the "after").
* join-kernel — the same aggregation computed standalone by the vectorized
  one-hot segmented-sum (the TPU event_join kernel's algorithm, oracle path
  on CPU) — the upper bound the batch plane closes in on.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import Triggerflow, make_trigger, termination_event


def bench_noop(n_events: int = 100_000, action_plane: bool = False) -> Dict:
    """The Table-1 noop workload through the real TF-Worker.

    ``action_plane=False`` runs the per-fire action loop — the "before"
    figure the action plane is gated against in CI (and the configuration
    the pre-action-plane ``load_test.noop`` baseline was committed with).
    """
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("load")
    tf.add_trigger("load", make_trigger(
        "e", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="noop", transient=False))
    events = [termination_event("e", i) for i in range(n_events)]
    tf.event_store.publish_batch("load", events)
    w = tf.worker("load")
    w.keep_event_log = False
    w.action_plane = action_plane
    t0 = time.perf_counter()
    done = 0
    while done < n_events:
        done += w.run_once(4096)
    dt = time.perf_counter() - t0
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt}


def bench_join(n_triggers: int = 100, events_each: int = 1000,
               batch_plane: bool = True) -> Dict:
    """The Table-1 join workload through the real TF-Worker.

    ``batch_plane=False`` runs the legacy per-event interpreter loop — the
    "before" figure the batch plane is gated against in CI.
    """
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("join")
    for t in range(n_triggers):
        tf.add_trigger("join", make_trigger(
            f"j{t}",
            condition={"name": "counter", "expected": events_each,
                       "aggregate": False},
            action={"name": "noop"}, trigger_id=f"jt{t}", transient=False))
    events = [termination_event(f"j{i % n_triggers}", i)
              for i in range(n_triggers * events_each)]
    tf.event_store.publish_batch("join", events)
    w = tf.worker("join")
    w.batch_plane = batch_plane
    w.keep_event_log = False
    n_events = len(events)
    t0 = time.perf_counter()
    done = 0
    while done < n_events:
        done += w.run_once(4096)
    dt = time.perf_counter() - t0
    fired = w.stats.fires
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt,
            "fired": fired}


def bench_join_vectorized(n_triggers: int = 100, events_each: int = 1000) -> Dict:
    """The event_join kernel algorithm (oracle path) on the same workload."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.event_join.ref import join_counts_ref

    n_events = n_triggers * events_each
    events = np.arange(n_events, dtype=np.int32) % n_triggers
    counts = jnp.zeros((n_triggers,), jnp.int32)
    expected = jnp.full((n_triggers,), events_each, jnp.int32)
    f = jax.jit(join_counts_ref)
    ev = jnp.asarray(events)
    f(ev, counts, expected)[0].block_until_ready()  # warm
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        nc, fired = f(ev, counts, expected)
    nc.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    assert int(fired.sum()) == n_triggers
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt}


def run(reps: int = 3) -> List[Dict]:
    # Interleave the before/after variants and keep the best events/s of
    # each: single runs on small shared machines swing ±25% from CPU steal,
    # which would drown the deltas being measured.
    best_interp = best_batch = best_noop = best_noop_ap = 0.0
    for _ in range(reps):
        before = bench_join(batch_plane=False)
        after = bench_join(batch_plane=True)
        assert before["fired"] == after["fired"] == 100, (before, after)
        best_interp = max(best_interp, before["events_per_s"])
        best_batch = max(best_batch, after["events_per_s"])
        best_noop = max(best_noop, bench_noop()["events_per_s"])
        best_noop_ap = max(best_noop_ap,
                           bench_noop(action_plane=True)["events_per_s"])

    rows = []
    rows.append({"name": "load_test.noop", "us_per_call": 1e6 / best_noop,
                 "events_per_s": best_noop,
                 "derived": f"{best_noop:.0f} events/s "
                            f"(per-fire actions, best of {reps})"})
    rows.append({"name": "load_test.noop_action_plane",
                 "us_per_call": 1e6 / best_noop_ap,
                 "events_per_s": best_noop_ap,
                 "derived": f"{best_noop_ap:.0f} events/s "
                            f"({best_noop_ap / best_noop:.1f}x vs per-fire "
                            f"actions, best of {reps})"})
    rows.append({"name": "load_test.join_interpreter",
                 "us_per_call": 1e6 / best_interp,
                 "events_per_s": best_interp,
                 "derived": f"{best_interp:.0f} events/s "
                            f"(per-event interpreter, best of {reps})"})
    rows.append({"name": "load_test.join",
                 "us_per_call": 1e6 / best_batch,
                 "events_per_s": best_batch,
                 "derived": f"{best_batch:.0f} events/s "
                            f"({best_batch / best_interp:.1f}x vs interpreter, "
                            f"best of {reps})"})
    vec = bench_join_vectorized()
    rows.append({"name": "load_test.join_vectorized_kernel_algo",
                 "us_per_call": 1e6 / vec["events_per_s"],
                 "events_per_s": vec["events_per_s"],
                 "derived": f"{vec['events_per_s']:.0f} events/s "
                            f"({vec['events_per_s'] / best_interp:.0f}x "
                            f"vs interpreter)"})
    return rows
