"""Fig 17 reproduction: Federated Learning orchestration — 50 heterogeneous,
unreliable clients, 3 rounds, 65% aggregation threshold, round timeout.

Clients *really train*: each holds a private shard of a synthetic logistic-
regression dataset and runs local SGD (numpy); the aggregator trigger fires at
the threshold (or on timeout in the failure-heavy round 3) and averages the
weight deltas from the object store.  Derived output: per-round client counts,
timeout flags, and the global model's accuracy trajectory.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import Triggerflow
from repro.core.fedlearn import FederatedLearningOrchestrator, ObjectStore

N_CLIENTS = 50
ROUNDS = 3
THRESHOLD = 0.65
TIMEOUT_S = 2.0
DIM = 16


def _make_data(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=DIM)
    shards = []
    for c in range(N_CLIENTS):
        X = rng.normal(size=(200, DIM))
        y = (X @ w_true + 0.1 * rng.normal(size=200) > 0).astype(np.float64)
        shards.append((X, y))
    Xt = rng.normal(size=(2000, DIM))
    yt = (Xt @ w_true > 0).astype(np.float64)
    return shards, (Xt, yt)


def _accuracy(w, Xt, yt) -> float:
    return float((((Xt @ w) > 0) == yt).mean())


def run() -> List[Dict]:
    shards, (Xt, yt) = _make_data()
    store = ObjectStore()
    rng = np.random.default_rng(7)
    acc_log: List[float] = []

    def client(args):
        rnd, cid = args["round"], args["client"]
        time.sleep(float(rng.uniform(0.02, 0.6)))      # heterogeneous speeds
        if rnd == 2 and cid % 2 == 0:                  # round 3: mass failures
            raise RuntimeError("client connection lost")
        w = np.asarray(store.get(args["model"]))
        X, y = shards[cid]
        for _ in range(5):                             # local SGD epochs
            p = 1 / (1 + np.exp(-(X @ w)))
            w = w - 0.5 * X.T @ (p - y) / len(y)
        key = store.put(f"delta/{rnd}/{cid}", w.tolist())
        return {"round": rnd, "result": key}

    def aggregate(keys, st):
        ws = np.stack([np.asarray(st.get(k)) for k in keys])
        w = ws.mean(0)
        acc_log.append(_accuracy(w, Xt, yt))
        return w.tolist()

    tf = Triggerflow()
    fl = FederatedLearningOrchestrator(
        tf, "flbench", client, aggregate, n_clients=N_CLIENTS, rounds=ROUNDS,
        threshold=THRESHOLD, round_timeout=TIMEOUT_S, object_store=store)
    fl.deploy()
    w0 = np.zeros(DIM)
    acc0 = _accuracy(w0, Xt, yt)
    t0 = time.perf_counter()
    out = fl.start(init_model=w0.tolist(), timeout=120)
    dt = time.perf_counter() - t0
    assert out["status"] == "succeeded", out
    rounds_info = "; ".join(
        f"r{r['round']}:{r['n_results']}/{N_CLIENTS}"
        f"{'(timeout)' if r['timed_out'] else ''}" for r in fl.round_log)
    tf.shutdown()
    return [{
        "name": "fedlearn.orchestrator",
        "us_per_call": dt / (N_CLIENTS * ROUNDS) * 1e6,
        "derived": (f"acc {acc0:.2f}->{acc_log[-1]:.2f} over {ROUNDS} rounds "
                    f"[{rounds_info}] wall={dt:.1f}s"),
    }]
