"""Fig 14/15/16 reproduction: the Montage astronomy workflow as a nested
Amazon-States-Language state machine — three parallel RGB branches, each
running project(map) → fit(map) → bgmodel → background(map) → add; then a
final mJPEG.  Run on Triggerflow with the KEDA autoscaler: the worker scales
to zero while the long tasks run, and function-level parallelism exceeds the
sequential baseline's.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

from repro.core import KedaAutoscaler, Triggerflow
from repro.core.statemachine import StateMachine

TILE_W = 6          # tiles per channel (paper: dozens)
SHORT_S = 0.05      # mImgtbl-style metadata steps
LONG_S = 0.4        # mProjExec/mDiffFit-style compute steps

_live = {"n": 0, "peak": 0, "lock": threading.Lock()}


def _task(seconds):
    def fn(x):
        with _live["lock"]:
            _live["n"] += 1
            _live["peak"] = max(_live["peak"], _live["n"])
        time.sleep(seconds)
        with _live["lock"]:
            _live["n"] -= 1
        return x if not isinstance(x, list) else len(x)

    return fn


def _channel(ch: str) -> Dict:
    return {
        "StartAt": "Tiles",
        "States": {
            "Tiles": {"Type": "Pass", "Result": list(range(TILE_W)),
                      "Next": "Project"},
            "Project": {"Type": "Map", "Next": "FitPlane", "Iterator": {
                "StartAt": "P1", "States": {
                    "P1": {"Type": "Task", "Resource": "long", "End": True}}}},
            "FitPlane": {"Type": "Task", "Resource": "short", "Next": "DiffTiles"},
            "DiffTiles": {"Type": "Pass", "Result": list(range(TILE_W)),
                          "Next": "DiffFit"},
            "DiffFit": {"Type": "Map", "Next": "BgModel", "Iterator": {
                "StartAt": "D1", "States": {
                    "D1": {"Type": "Task", "Resource": "long", "End": True}}}},
            "BgModel": {"Type": "Task", "Resource": "short", "Next": "MAdd"},
            "MAdd": {"Type": "Task", "Resource": "long", "End": True},
        },
    }


def run() -> List[Dict]:
    tf = Triggerflow(commit_policy="every_batch")
    tf.backend.register("short", _task(SHORT_S))
    tf.backend.register("long", _task(LONG_S))
    defn = {
        "StartAt": "RGB",
        "States": {
            "RGB": {"Type": "Parallel", "Next": "MJpeg",
                    "Branches": [_channel("r"), _channel("g"), _channel("b")]},
            "MJpeg": {"Type": "Task", "Resource": "short", "End": True},
        },
    }
    sm = StateMachine(defn)
    sm.deploy(tf, "montage")
    _live["peak"] = 0
    scaler = KedaAutoscaler(tf, poll_interval=0.03, grace_period=0.15,
                            max_workers=4).start()
    t0 = time.perf_counter()
    tf.init_workflow("montage")
    while True:
        w = tf._workers.get("montage")
        if w is not None and w.finished:
            break
        if time.perf_counter() - t0 > 120:
            raise TimeoutError("montage did not finish")
        time.sleep(0.02)
    dt = time.perf_counter() - t0
    time.sleep(0.5)
    scaler._tick()
    scaler.stop()
    res = tf.get_state("montage")
    assert res["status"] == "succeeded", res
    zero_samples = sum(1 for _, n, _ in scaler.timeline if n == 0)
    worker_samples = len(scaler.timeline)
    # serial baseline: every task in sequence
    serial = (3 * (TILE_W * 2 + 1) * LONG_S + 3 * 2 * SHORT_S + SHORT_S)
    tf.shutdown()
    return [{
        "name": "montage.nested_sm",
        "us_per_call": dt * 1e6 / (3 * (2 * TILE_W + 3) + 1),
        "derived": (f"wall={dt:.2f}s serial={serial:.2f}s "
                    f"speedup={serial / dt:.1f}x peak_parallel_fns={_live['peak']} "
                    f"scale_to_zero_samples={zero_samples}/{worker_samples}"),
    }]
