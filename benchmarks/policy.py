"""Failure-policy plane overhead: the Table-1 noop action-plane workload with
a retry policy attached but never triggered.

Two rows through the real TF-Worker on the action plane (the fastest
committed path, so any per-batch cost the policy plane adds is maximally
visible):

* policy_off — no retry policy (the committed baseline configuration).
* policy_idle — every trigger carries ``RetryPolicy(max_attempts=3)`` but no
  action ever fails: the policy plane's fixed costs (per-entry compile, the
  per-event success hook, the defer-filter's empty-map check) are all that
  can show.  Gated in CI at >= 0.90x of policy_off (``scripts/perf_gate.py``).

Note the idle policy deliberately leaves ``action_timeout`` unset: a timeout
moves every attempt onto a watchdog thread, which is a real (documented)
cost, not plane overhead.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core import Triggerflow, make_trigger, termination_event


def bench_policy_noop(n_events: int = 100_000,
                      retry: Optional[dict] = None) -> Dict:
    """``obs.bench_obs_noop`` with a retry policy toggled instead of the
    metrics plane (metrics stay at their default — identical in both rows)."""
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("load")
    tf.add_trigger("load", make_trigger(
        "e", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="noop", transient=False, retry=retry))
    events = [termination_event("e", i) for i in range(n_events)]
    tf.event_store.publish_batch("load", events)
    w = tf.worker("load")
    w.keep_event_log = False
    w.action_plane = True
    t0 = time.perf_counter()
    done = 0
    while done < n_events:
        done += w.run_once(4096)
    dt = time.perf_counter() - t0
    return {"events": n_events, "seconds": dt, "events_per_s": n_events / dt}


IDLE_POLICY = {"max_attempts": 3, "backoff_base": 0.05}


def run(reps: int = 3) -> List[Dict]:
    # Interleaved best-of (same rationale as load_test.run / obs.run).
    best = {"off": 0.0, "idle": 0.0}
    for _ in range(reps):
        best["off"] = max(best["off"],
                          bench_policy_noop()["events_per_s"])
        best["idle"] = max(best["idle"],
                           bench_policy_noop(retry=IDLE_POLICY)["events_per_s"])
    return [
        {"name": "policy.noop_policy_off", "us_per_call": 1e6 / best["off"],
         "events_per_s": best["off"],
         "derived": f"{best['off']:.0f} events/s "
                    f"(no retry policy, best of {reps})"},
        {"name": "policy.noop_policy_idle", "us_per_call": 1e6 / best["idle"],
         "events_per_s": best["idle"],
         "derived": f"{best['idle']:.0f} events/s (idle retry policy, "
                    f"{best['idle'] / best['off']:.2f}x of policy-off, "
                    f"best of {reps})"},
    ]
