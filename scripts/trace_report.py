"""Reconstruct trigger span trees from span segment files.

Reads JSONL span records (per-shard ``spans.<member>.jsonl`` segments under
a process pool's ``<root>/spans`` dir, or files exported with
``SpanCollector.export_jsonl``), stitches them — deduplicating by span id,
completed records winning over their open pre-crash twins — and prints one
ASCII tree per trace.

    PYTHONPATH=src python scripts/trace_report.py <paths...> [--assert-connected]

``--assert-connected`` exits non-zero if any trace has more than one
attachment point (a broken causal chain) — the CI smoke uses this to prove
end-to-end propagation across shards, processes and crash/replay.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.trace import load_spans, render_tree, span_trees, stitch_spans


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+",
                    help="span JSONL files, or directories of *.jsonl")
    ap.add_argument("--assert-connected", action="store_true",
                    help="exit 1 if any trace is not a single connected tree")
    ap.add_argument("--quiet", action="store_true",
                    help="summary only, no per-trace trees")
    args = ap.parse_args(argv)

    spans = stitch_spans(load_spans(args.paths))
    if not spans:
        print("no spans found")
        return 1 if args.assert_connected else 0
    trees = span_trees(spans)
    disconnected = []
    for trace_id in sorted(trees):
        tree = trees[trace_id]
        status = "connected" if tree["connected"] else \
            "DISCONNECTED (%d attachment points)" % len(tree["attachments"])
        print(f"trace {trace_id}: {tree['spans']} spans, {status}")
        if not tree["connected"]:
            disconnected.append(trace_id)
        if not args.quiet:
            trace = [s for s in spans if s["trace"] == trace_id]
            print(render_tree(tree, trace))
    print(f"{len(trees)} trace(s), {len(spans)} span(s), "
          f"{len(disconnected)} disconnected")
    if args.assert_connected and disconnected:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
