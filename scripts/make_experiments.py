"""Generate EXPERIMENTS.md from results/ (dry-run cells, hillclimb logs,
benchmark rows).  Run after `benchmarks.run` and `launch.hillclimb`.

    PYTHONPATH=src python scripts/make_experiments.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import load_cells, markdown_table  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
RES = os.path.join(ROOT, "results")


def benchmarks_section() -> str:
    path = os.path.join(RES, "benchmarks.json")
    if not os.path.exists(path):
        return "_(run `python -m benchmarks.run` first)_"
    rows = json.load(open(path))
    out = ["| benchmark | µs/call | result |", "|---|---|---|"]
    for r in rows:
        if r["name"].startswith("roofline."):
            continue
        out.append(f"| {r['name']} | {r['us_per_call']:.1f} | {r['derived']} |")
    return "\n".join(out)


def dryrun_section() -> str:
    out = []
    for mesh, label in (("single", "16×16 (256 chips)"),
                        ("multi", "2×16×16 (512 chips, multi-pod)")):
        cells = load_cells(mesh)
        ok = sum(1 for c in cells if c["status"] == "ok")
        skip = sum(1 for c in cells if c["status"] == "skipped")
        fail = sum(1 for c in cells if c["status"] not in ("ok", "skipped"))
        out.append(f"**{label}**: {ok} compiled OK, {skip} skipped "
                   f"(long_500k on full-attention archs), {fail} failed.")
        if mesh == "multi":
            out.append("")
            out.append("| arch | shape | compile | HBM/dev (GB) | dominant |")
            out.append("|---|---|---|---|---|")
            for c in cells:
                if c["status"] == "ok":
                    gb = c["memory"]["peak_est_bytes"] / 2 ** 30
                    out.append(f"| {c['arch']} | {c['shape']} | ok "
                               f"({c['compile_s']}s) | {gb:.1f} | "
                               f"{c['dominant'][2:]} |")
                elif c["status"] == "skipped":
                    out.append(f"| {c['arch']} | {c['shape']} | skipped | — | — |")
                else:
                    out.append(f"| {c['arch']} | {c['shape']} | FAILED | — | — |")
        out.append("")
    return "\n".join(out)


def perf_section() -> str:
    files = sorted(glob.glob(os.path.join(RES, "hillclimb", "*.json")))
    if not files:
        return "_(run `python -m repro.launch.hillclimb --all` first)_"
    by_cell = {}
    for f in files:
        r = json.load(open(f))
        key = os.path.basename(f).split("__")[0]
        by_cell.setdefault(key, []).append(r)
    out = []
    for key, runs in sorted(by_cell.items()):
        base = next(r for r in runs if r.get("variant") == "baseline")
        bt = base["roofline"]
        dom = base["dominant"]
        out.append(f"### {base['arch']} × {base['shape']}")
        out.append(f"*Why this cell:* {base.get('hypothesis', '')}")
        out.append("")
        out.append("| variant | hypothesis | t_compute | t_memory | "
                   "t_collective | Δ dominant | verdict |")
        out.append("|---|---|---|---|---|---|---|")
        for r in runs:
            if r.get("status") != "ok":
                out.append(f"| {r.get('variant')} | {r.get('hypothesis', '')[:90]} "
                           f"| FAILED | | | | refuted (compile error) |")
                continue
            t = r["roofline"]
            if r["variant"] == "baseline":
                out.append(f"| **baseline** | (paper-faithful defaults) | "
                           f"{t['t_compute']:.3e} | {t['t_memory']:.3e} | "
                           f"{t['t_collective']:.3e} | — | — |")
                continue
            delta = (t[dom] - bt[dom]) / bt[dom] * 100
            best = max(t["t_compute"], t["t_memory"], t["t_collective"])
            bbase = max(bt["t_compute"], bt["t_memory"], bt["t_collective"])
            verdict = "confirmed" if delta < -5 else (
                "neutral" if delta < 5 else "refuted")
            out.append(f"| {r['variant']} | {r['hypothesis'][:120]} | "
                       f"{t['t_compute']:.3e} | {t['t_memory']:.3e} | "
                       f"{t['t_collective']:.3e} | {delta:+.1f}% | {verdict} "
                       f"(step {bbase / best:.2f}× vs base) |")
        out.append("")
    return "\n".join(out)


def main() -> None:
    md = f"""# EXPERIMENTS

Environment: CPU-only container (jax {__import__('jax').__version__}),
TPU v5e as the modelled target (197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI).  All multi-device results use
`--xla_force_host_platform_device_count=512` placeholder devices; nothing in
the dry-run allocates real arrays (ShapeDtypeStruct lowering only).

## §Paper-validation (Triggerflow control plane)

One benchmark per paper table/figure (see DESIGN.md §7 for the index).
Tasks are scaled 20× shorter than the paper's (0.15 s vs 3 s etc.) so the
suite runs in minutes; overheads are absolute.

{benchmarks_section()}

Paper claims checked:
* **Table 1** — a single worker sustains ~3×10⁵ noop events/s and ~2.5×10⁵
  aggregation-join events/s on one core (paper: 1.6×10⁴/s Redis·1-core to
  7.5×10⁴/s Kafka·2-core). Same order, same noop≥join ordering.
  The vectorized one-hot join (our TPU `event_join` kernel's algorithm)
  processes the identical workload >1000× faster — the §2 hardware adaptation.
* **Fig 9** — sequence overhead grows linearly at ~1-1.6 ms/step, sitting
  between the always-on direct baseline (floor) and the Lithops-style poller,
  as in the paper.
* **Fig 10** — per-task parallel overhead *falls* with fan-out and beats the
  poller at n≥80: trigger joins suit massively-parallel fork-join (the
  paper's headline claim).
* **Fig 11/12** — native-scheduler replay beats the external scheduler's
  store re-reads; `store_requests` grows as n (vs the paper's n(n+1)/2
  COS pathology).
* **Fig 8** — workers scale 0→40→0 with event pressure; scale-to-zero
  observed while actions run.
* **Fig 13** — worker killed mid-map: recovery from checkpointed contexts +
  uncommitted-event replay finishes with **0 task re-runs** (Lithops-style
  baseline re-runs all 12).
* **Fig 14-16** — nested Montage state machine completes with 11× parallel
  speedup and the worker scaled to zero during long tasks.
* **Fig 17** — FL rounds fire at the 65% threshold; the failure-heavy round
  is released by the timeout event; global model accuracy 0.52→0.99.

## §Dry-run

`python -m repro.launch.dryrun --all [--multi-pod]` lowers + compiles every
(arch × shape) with production shardings.  A cell = `train_step` (train_4k)
or `serve_step` (prefill/decode shapes).

{dryrun_section()}

## §Roofline (single-pod 16×16, per device)

Terms are derived from unrolled **affine probes** (two small-depth unrolled
compiles, extrapolated linearly in layer count) because XLA's
`cost_analysis()` counts `while`-loop bodies once — see DESIGN.md §6.
`useful_flops` = analytic MODEL_FLOPS / extrapolated HLO FLOPs (remat
recompute, attention padding waste and MoE capacity padding all show up
here).  `roofline_frac` = ideal compute time / max(term) — the score axis.
`collective` assumes ring all-reduce (2× on-wire factor).

{markdown_table("single")}

Reading of the baseline table:
* **prefill_32k** cells are the healthiest (frac 0.04–0.16, memory-bound —
  flash-attention bytes dominate; useful_flops ≈ 1.0 for dense archs).
* **train_4k** cells are collective-bound across the board: fp32 gradient
  all-reduces of the unsharded embedding/LM-head gradients and FSDP
  weight all-gathers dominate (the §Perf cells attack exactly this).
* **decode** cells are memory/collective-bound as expected (1 token reads
  the whole cache); deepseek-67b's baseline showed a pathological 2 GB
  KV-cache all-gather per layer — fixed in §Perf cell C.
* MoE cells (phi3.5, dsv2) have the worst useful_flops (0.13-0.34):
  capacity-factor padding + dispatch gathers; cell B attacks this.

## §Perf — hillclimb log (hypothesis → change → measure → verdict)

Three cells per the brief: worst fraction (A), most collective-bound (B),
most serving-representative (C).  The **baseline rows are the
paper-faithful configuration**; variants are beyond-paper optimizations.
Δ is on the baseline's dominant term; "step ×" is the modelled step-time
speedup (max-term ratio).

{perf_section()}

### Per-cell conclusions & next levers

* **Cell A (zamba2 train, memory-bound).** A study in refuted hypotheses
  converging on a structural conclusion: remat policy (−3.2%), chunk size up
  (+0.9%), chunk size down (−0.1%), FSDP extent (−0.0%) and even bf16-ifying
  the decay chain (+0.2% — XLA reinstates f32 converts around `exp`/`cumsum`,
  paying back the savings) ALL fail to move t_memory materially.  Conclusion:
  the bytes are spread across the SSD einsum operands themselves
  ([B,nc,Q,Q,H] decay, [B,nc,Q,H,P] gated inputs, fwd+bwd), so no high-level
  knob wins — the fix is a **fused Pallas SSD kernel** where decay tiles
  never leave VMEM.  This is precisely why Mamba2's reference implementation
  is a fused kernel; our hypothesis loop rediscovered that from the roofline
  side — and we then **implemented it**: `kernels/ssd` computes a whole SSD
  chunk (cumulative decays, masked decay tile, G=C·Bᵀ, running [N,P] state)
  per grid step in VMEM, validated in interpret mode against both the time
  recurrence and the production XLA path (`tests/test_ssd_kernel.py`).  On
  TPU this removes every intra-chunk HBM round-trip the XLA path pays.
  Confirmed in-XLA winner meanwhile: dots-remat (compute −20%, memory −3%).
* **Cell B (deepseek-v2 train, collective-bound).** Capacity factor 1.25→1.0
  cut dispatch + expert padding traffic 14%; dropping activation
  seq-sharding removed the per-layer seq↔heads all-to-alls for another 9%
  (at +38% memory, a real trade); expert-parallelism over the data axis was
  **refuted** (+62% collectives — the gather then fights the FSDP layout).
  Next lever: bf16 gradient reduce-scatter + sharded embedding-gradient
  accumulation (the remaining fp32 [V,D] all-reduces).
* **Cell C (deepseek-67b decode, the serving cell).** One sharding-rule
  line (KV-cache seq→model) converted 2 GB/layer cache all-gathers into
  partial-softmax stat reductions: collective −99.7% (308×), memory −87%,
  modelled per-token step 4.1 s → 0.20 s (≈21× end-to-end).  Next lever:
  int8 KV cache (halves the now-dominant cache-read bytes).

### Beyond-paper summary

The paper's contribution is the control plane; its data plane is opaque
cloud functions.  Our beyond-paper work is therefore all on the JAX data
plane: (1) seq-sharded KV caches for decode (308× collective reduction),
(2) MoE capacity/dispatch tuning (1.29× step on dsv2 train), (3) bf16 SSD
decay chains for memory-bound SSM training, (4) triangular-schedule
unrolled flash attention (causal block skip, ~2× attention FLOPs saved at
long context), and (5) the vectorized event-join formulation of the paper's
own hot loop (>1000× on the Table-1 workload, and a Pallas TPU kernel).
"""
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(md)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
