"""CI perf-regression gate: batch plane, action plane, process bus,
observability, failure policy, the replicated segment transport, the TFB1
event-codec decode advantage and the tfcheck lock tracer's flag-off
zero-cost guarantee.

Three gated ratios, all measured through the real runtimes within one job:

* join  — per-event interpreter (``batch_plane=False``) vs batch plane
          (Table-1 join workload, 100 triggers x 1000 events).
* noop  — per-fire action loop (``action_plane=False``) vs action plane
          (fire-run conditions + batched actions, Table-1 noop workload).
* proc  — 2 threaded shards (in-memory bus) vs 2 shard *processes* over the
          durable file-backed bus (``sharded_load --mode=process``): guards
          the multiprocess runtime + file-bus hot path against regressions
          (a broken sync/commit path or serialization blow-up collapses the
          ratio).

Plus one *deterministic* check (no committed baseline, no host-speed
dependence): an idle autoscaler lag poll on the file bus must cost O(1) stat
calls — the publish-notify gate — not O(partitions) disk probes.  Measured
by counting ``os.path.getsize`` calls across idle ``lag()`` polls at 8 and
64 partitions; any growth with partition width fails the job.

Each measured speedup is compared against the one committed in
``results/benchmarks.json``.  The gate is on the *ratio*, not raw events/s:
CI runners differ by far more than 30% in absolute speed, but before and
after share the machine within one job, so their ratio cancels host speed
out.  A >30% drop in any ratio fails the job.

    PYTHONPATH=src:. python scripts/perf_gate.py [--reps 2] [--tolerance 0.7]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def committed_ratio(path: str, before_row: str, after_row: str):
    try:
        with open(path) as f:
            rows = json.load(f)
        by_name = {r.get("name"): r for r in rows if isinstance(r, dict)}
        before = by_name[before_row]["events_per_s"]
        after = by_name[after_row]["events_per_s"]
    except (OSError, ValueError, KeyError, TypeError):
        # absent/malformed baseline: report, skip the gate, stay green
        return None, None, None
    return after / before, before, after


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--tolerance", type=float, default=0.7,
                    help="fail if a measured speedup < tolerance * committed")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "benchmarks.json"))
    args = ap.parse_args()

    from benchmarks.load_test import bench_join, bench_noop
    from benchmarks.sharded_load import bench_proc_noop, bench_sharded_noop

    join_interp = join_batch = noop_scalar = noop_ap = 0.0
    thread2 = proc2 = 0.0
    for _ in range(args.reps):
        join_interp = max(join_interp,
                          bench_join(batch_plane=False)["events_per_s"])
        join_batch = max(join_batch,
                         bench_join(batch_plane=True)["events_per_s"])
        noop_scalar = max(noop_scalar,
                          bench_noop(action_plane=False)["events_per_s"])
        noop_ap = max(noop_ap,
                      bench_noop(action_plane=True)["events_per_s"])
        thread2 = max(thread2, bench_sharded_noop(
            n_events=20_000, shards=2, partitions=8,
            subjects=32)["events_per_s"])
        proc2 = max(proc2, bench_proc_noop(
            n_events=20_000, shards=2, partitions=8, subjects=32,
            batch_size=1024)["events_per_s"])

    gates = [
        # (label, before ev/s, after ev/s, committed before/after row names)
        ("join (batch plane)", join_interp, join_batch,
         "load_test.join_interpreter", "load_test.join"),
        ("noop (action plane)", noop_scalar, noop_ap,
         "load_test.noop", "load_test.noop_action_plane"),
        ("noop (2 process shards vs 2 thread shards)", thread2, proc2,
         "sharded_load.noop_2shard", "sharded_load.noop_2proc_file"),
    ]

    lines = [
        "## Perf gate (batch plane + action plane + process bus)",
        "",
        "| scenario | before ev/s | after ev/s | speedup | committed |",
        "|---|---|---|---|---|",
    ]
    failures = []
    any_ref = False
    for label, before, after, ref_before_row, ref_after_row in gates:
        speedup = after / before
        ref_speedup, _, _ = committed_ratio(
            args.baseline, ref_before_row, ref_after_row)
        ref_txt = "—"
        if ref_speedup is not None:
            any_ref = True
            ref_txt = f"{ref_speedup:.2f}x"
            floor = args.tolerance * ref_speedup
            if speedup < floor:
                failures.append(
                    f"{label}: measured speedup {speedup:.2f}x is below "
                    f"{args.tolerance:.0%} of committed {ref_speedup:.2f}x "
                    f"(floor {floor:.2f}x) -> >30% perf regression")
        lines.append(f"| {label} | {before:,.0f} | {after:,.0f} | "
                     f"**{speedup:.2f}x** | {ref_txt} |")
    summary = "\n".join(lines) + "\n"
    print(summary)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary)

    # observability overhead gate: the metrics plane on the noop action
    # plane must keep >= 90% of the metrics-off throughput.  An *absolute*
    # ratio floor (the two variants share the host within one job, so host
    # speed cancels) — no committed baseline needed.
    from benchmarks.obs import bench_obs_noop
    obs_off = obs_on = 0.0
    for _ in range(args.reps):
        obs_off = max(obs_off,
                      bench_obs_noop(n_events=50_000,
                                     metrics=False)["events_per_s"])
        obs_on = max(obs_on,
                     bench_obs_noop(n_events=50_000,
                                    metrics=True)["events_per_s"])
    obs_ratio = obs_on / obs_off
    obs_line = (f"observability overhead: metrics-on {obs_on:,.0f} ev/s vs "
                f"metrics-off {obs_off:,.0f} ev/s = {obs_ratio:.2f}x "
                f"(floor 0.90x)\n")
    if obs_ratio < 0.9:
        failures.append(
            f"observability: metrics-on ratio {obs_ratio:.2f}x is below the "
            f"0.90x floor -> metrics plane costs >10% on the noop action plane")
    print(obs_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + obs_line)

    # failure-policy plane overhead gate: a retry policy that never fires
    # must keep >= 90% of the no-policy noop action-plane throughput.  Same
    # absolute-ratio construction as the observability gate above.
    from benchmarks.policy import IDLE_POLICY, bench_policy_noop
    pol_off = pol_idle = 0.0
    for _ in range(args.reps):
        pol_off = max(pol_off,
                      bench_policy_noop(n_events=50_000)["events_per_s"])
        pol_idle = max(pol_idle,
                       bench_policy_noop(n_events=50_000,
                                         retry=IDLE_POLICY)["events_per_s"])
    pol_ratio = pol_idle / pol_off
    pol_line = (f"failure-policy overhead: policy-idle {pol_idle:,.0f} ev/s vs "
                f"policy-off {pol_off:,.0f} ev/s = {pol_ratio:.2f}x "
                f"(floor 0.90x)\n")
    if pol_ratio < 0.9:
        failures.append(
            f"failure-policy: idle-policy ratio {pol_ratio:.2f}x is below the "
            f"0.90x floor -> retry plumbing costs >10% when never triggered")
    print(pol_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + pol_line)

    # replication overhead gate: shipping every segment mutation to a live
    # replica through the pipelined client must keep >= 85% of the
    # replication-off file-bus throughput.  Absolute ratio floor, but gated
    # on the best *paired* ratio (each off/on measured back to back, ratio
    # per pair): pairing cancels machine-state drift (frequency scaling,
    # cache, background load) that max-of-each-side pairing does not — the
    # best pair is the honest floor of the transport's overhead.
    from benchmarks.replication import bench_replicated_bus
    rep_ratio = rep_off = rep_on = 0.0
    for _ in range(args.reps):
        pair_off = bench_replicated_bus(n_events=50_000)["events_per_s"]
        pair_on = bench_replicated_bus(n_events=50_000,
                                       replicate=True)["events_per_s"]
        if pair_on / pair_off > rep_ratio:
            rep_ratio = pair_on / pair_off
            rep_off, rep_on = pair_off, pair_on
    rep_line = (f"replication overhead: replication-on {rep_on:,.0f} ev/s vs "
                f"replication-off {rep_off:,.0f} ev/s = {rep_ratio:.2f}x "
                f"(floor 0.85x)\n")
    if rep_ratio < 0.85:
        failures.append(
            f"replication: pipelined-transport ratio {rep_ratio:.2f}x is "
            f"below the 0.85x floor -> shipping costs >15% on the file bus")
    print(rep_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + rep_line)

    # event-codec decode gate: TFB1 columnar frames must decode (and
    # materialize) at >= 2x the v1 JSON-lines rate — the headline win the
    # binary format exists for.  Absolute floor on the best *paired* ratio
    # (both sides of each pair measured in one bench_codec call, so host
    # speed cancels exactly).
    from benchmarks.codec import bench_codec
    cod_ratio = cod_json = cod_frame = 0.0
    for _ in range(args.reps):
        m = bench_codec(n_events=100_000)
        if m["dec_frame"] / m["dec_json"] > cod_ratio:
            cod_ratio = m["dec_frame"] / m["dec_json"]
            cod_json, cod_frame = m["dec_json"], m["dec_frame"]
    cod_line = (f"codec decode: TFB1 frames {cod_frame:,.0f} ev/s vs v1 JSON "
                f"{cod_json:,.0f} ev/s = {cod_ratio:.2f}x (floor 2.00x)\n")
    if cod_ratio < 2.0:
        failures.append(
            f"codec: TFB1 decode ratio {cod_ratio:.2f}x is below the 2.00x "
            f"floor -> the binary format lost its decode advantage")
    print(cod_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + cod_line)

    # tfcheck lock-trace zero-cost gate: with TFCHECK_TRACE_LOCKS unset,
    # importing repro.analysis.locktrace and calling maybe_install() must
    # patch nothing — threading.Lock/RLock, fcntl.flock and time.sleep stay
    # the real primitives.  The sharp assertion is *identity*: after
    # maybe_install(), the primitives must literally still be the originals.
    # On top of that, noop action-plane throughput must hold within 2% of a
    # run that never called into the tracer — best *paired* ratio like the
    # replication gate above, but since the two sides run identical code a
    # 2% floor sits inside single-sample noise, so this gate uses >=5 pairs
    # of longer runs and alternates which side goes first within each pair
    # (monotone machine drift then cannot bias one side).
    os.environ.pop("TFCHECK_TRACE_LOCKS", None)
    import threading
    import time as _time
    from repro.analysis import locktrace
    installed = locktrace.maybe_install()
    if installed or locktrace.is_installed() or not (
            threading.Lock is locktrace._real_Lock
            and threading.RLock is locktrace._real_RLock
            and _time.sleep is locktrace._real_sleep):
        failures.append(
            "lock-trace: maybe_install() patched the primitives with "
            "TFCHECK_TRACE_LOCKS unset -> instrumentation is not "
            "compiled out")
    trace_ratio = trace_off = trace_on = 0.0
    for i in range(max(args.reps, 5)):
        sides = ("off", "on") if i % 2 == 0 else ("on", "off")
        pair = {}
        for side in sides:
            if side == "on":
                locktrace.maybe_install()
            pair[side] = bench_noop(n_events=200_000,
                                    action_plane=True)["events_per_s"]
        if pair["on"] / pair["off"] > trace_ratio:
            trace_ratio = pair["on"] / pair["off"]
            trace_off, trace_on = pair["off"], pair["on"]
    trace_line = (f"lock-trace off overhead: tracer-touched {trace_on:,.0f} "
                  f"ev/s vs untouched {trace_off:,.0f} ev/s = "
                  f"{trace_ratio:.2f}x (floor 0.98x)\n")
    if trace_ratio and trace_ratio < 0.98:
        failures.append(
            f"lock-trace: flag-unset ratio {trace_ratio:.2f}x is below the "
            f"0.98x floor -> the disabled tracer costs >2% on the noop "
            f"action plane")
    print(trace_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + trace_line)

    # deterministic idle-tick check: syscall counts, not wall time, so it
    # gates even when no committed baseline exists
    from benchmarks.autoscale import bench_idle_tick_stats
    try:
        idle = bench_idle_tick_stats(polls=100)
        idle_line = f"idle lag poll: {idle['derived']}\n"
    except AssertionError as exc:
        failures.append(f"idle lag poll: {exc}")
        idle_line = f"idle lag poll: FAILED ({exc})\n"
    print(idle_line, end="")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n" + idle_line)

    if failures:
        for f_msg in failures:
            print("FAIL:", f_msg)
        return 1
    if not any_ref:
        print("no committed baseline rows; ratio gates skipped")
        return 0
    print("OK: all gated ratios within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
