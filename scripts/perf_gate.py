"""CI perf-regression gate for the batch plane (Table-1 join workload).

Measures the join scenario through the real TF-Worker twice — per-event
interpreter (``batch_plane=False``) and batch plane — and compares the
speedup ratio against the one committed in ``results/benchmarks.json``.

The gate is on the *ratio*, not raw events/s: CI runners differ by far more
than 30% in absolute speed, but interpreter and batch plane share the
machine within one job, so their ratio cancels host speed out.  A >30% drop
in that ratio fails the job.

    PYTHONPATH=src:. python scripts/perf_gate.py [--reps 2] [--tolerance 0.7]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def committed_speedup(path: str):
    try:
        with open(path) as f:
            rows = json.load(f)
        by_name = {r.get("name"): r for r in rows if isinstance(r, dict)}
        interp = by_name["load_test.join_interpreter"]["events_per_s"]
        batch = by_name["load_test.join"]["events_per_s"]
    except (OSError, ValueError, KeyError, TypeError):
        # absent/malformed baseline: report, skip the gate, stay green
        return None, None, None
    return batch / interp, interp, batch


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--tolerance", type=float, default=0.7,
                    help="fail if measured speedup < tolerance * committed")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "benchmarks.json"))
    args = ap.parse_args()

    from benchmarks.load_test import bench_join

    interp = batch = 0.0
    for _ in range(args.reps):
        interp = max(interp, bench_join(batch_plane=False)["events_per_s"])
        batch = max(batch, bench_join(batch_plane=True)["events_per_s"])
    speedup = batch / interp

    ref_speedup, ref_interp, ref_batch = committed_speedup(args.baseline)
    lines = [
        "## Batch-plane perf gate (load_test.join, 100 triggers x 1000 events)",
        "",
        "| | interpreter ev/s | batch plane ev/s | speedup |",
        "|---|---|---|---|",
        f"| this run | {interp:,.0f} | {batch:,.0f} | **{speedup:.2f}x** |",
    ]
    if ref_speedup is not None:
        lines.append(f"| committed baseline | {ref_interp:,.0f} | "
                     f"{ref_batch:,.0f} | {ref_speedup:.2f}x |")
    summary = "\n".join(lines) + "\n"
    print(summary)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary)

    if ref_speedup is None:
        print("no committed baseline rows; gate skipped")
        return 0
    floor = args.tolerance * ref_speedup
    if speedup < floor:
        print(f"FAIL: measured speedup {speedup:.2f}x is below "
              f"{args.tolerance:.0%} of committed {ref_speedup:.2f}x "
              f"(floor {floor:.2f}x) -> >30% perf regression")
        return 1
    print(f"OK: speedup {speedup:.2f}x >= floor {floor:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
