#!/usr/bin/env python
"""tfcheck — the repo's invariant linter (static half; CI gate).

Runs the ``repro.analysis`` AST rules over ``src/repro/core`` and
``src/repro/bus`` (override with positional paths) and fails on any finding
not covered by the committed baseline (``tfcheck-baseline.json``) or an
inline ``# tfcheck: allow[rule] reason`` pragma.

    PYTHONPATH=src python scripts/tfcheck.py              # gate (CI mode)
    python scripts/tfcheck.py --list-rules                # the catalogue
    python scripts/tfcheck.py --write-baseline            # re-ratchet
    python scripts/tfcheck.py src/repro extra_dir/        # custom scope

Exit codes: 0 clean (or fully baselined), 1 new findings, 2 usage/IO error.

The dynamic half (runtime lock-order recording) is not here: set
``TFCHECK_TRACE_LOCKS=1`` and run the tier-1 suite — ``tests/conftest.py``
installs ``repro.analysis.locktrace`` and asserts an acyclic runtime lock
graph at session end.
"""
from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis import (ALL_RULES, load_baseline, load_paths,  # noqa: E402
                            ratchet, run_rules, write_baseline)

DEFAULT_PATHS = ("src/repro/core", "src/repro/bus")
DEFAULT_BASELINE = "tfcheck-baseline.json"


def list_rules() -> None:
    print("tfcheck rules (static; see docs/ARCHITECTURE.md §10):\n")
    for r in ALL_RULES:
        print("  %-20s %s" % (r.id, r.invariant))
        print("  %-20s motivation: %s\n" % ("", r.motivation))
    print("  %-20s %s" % (
        "lock-trace (dynamic)",
        "TFCHECK_TRACE_LOCKS=1 under pytest records the runtime lock "
        "acquisition graph"))
    print("  %-20s %s" % (
        "", "and asserts it is acyclic with no sleep under bus locks."))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to check (default: %s)"
                    % " ".join(DEFAULT_PATHS))
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, DEFAULT_BASELINE))
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current findings")
    ap.add_argument("--no-ratchet", action="store_true",
                    help="ignore the baseline; report everything")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()

    if args.list_rules:
        list_rules()
        return 0

    paths = args.paths or [os.path.join(REPO, p) for p in DEFAULT_PATHS]
    for p in paths:
        if not os.path.exists(p):
            print("tfcheck: no such path: %s" % p, file=sys.stderr)
            return 2
    try:
        files = load_paths(paths, root=REPO)
    except SyntaxError as exc:
        print("tfcheck: cannot parse: %s" % exc, file=sys.stderr)
        return 2

    findings = run_rules(files)
    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print("tfcheck: baseline written to %s (%d findings)"
              % (args.baseline, len(findings)))
        return 0

    baseline = {} if args.no_ratchet else load_baseline(args.baseline)
    new = ratchet(findings, baseline)
    if not args.quiet:
        for f in new:
            print(f.render())
    n_baselined = len(findings) - len(new)
    if new:
        print("tfcheck: %d finding(s) (%d more baselined) over %d files "
              "-> FAIL" % (len(new), n_baselined, len(files)))
        return 1
    if not args.quiet:
        print("tfcheck: clean (%d files, %d rules, %d baselined finding(s))"
              % (len(files), len(ALL_RULES), n_baselined))
    return 0


if __name__ == "__main__":
    sys.exit(main())
