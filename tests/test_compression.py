"""int8 delta-compression properties + FL-with-compression integration."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.distributed.compression import (apply_delta, compress_delta,
                                           compressed_bytes, dequantize_int8,
                                           quantize_int8)


@given(st.integers(0, 1000), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quantize_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=scale, size=64).astype(np.float32)
    packed = quantize_int8(x)
    err = np.abs(dequantize_int8(packed) - x).max()
    assert err <= packed["scale"] * 0.5 + 1e-7  # round-to-nearest bound
    # →4× asymptotically; the 8-byte scale header dominates tiny tensors
    assert compressed_bytes(packed) < x.nbytes / 3


def test_stochastic_rounding_unbiased():
    rng = np.random.default_rng(0)
    x = np.full(20_000, 0.3, np.float32)  # exactly between quant levels
    packed = quantize_int8(x, rng=rng)
    mean = dequantize_int8(packed).mean()
    assert abs(mean - 0.3) < 0.01


def test_delta_roundtrip():
    rng = np.random.default_rng(1)
    base = rng.normal(size=128)
    new = base + rng.normal(scale=0.01, size=128)  # small training delta
    packed = compress_delta(new, base)
    rec = apply_delta(base, packed)
    assert np.abs(rec - new).max() <= np.abs(new - base).max() / 254 + 1e-7


def test_fl_with_compressed_deltas():
    """End-to-end: FL clients ship int8 deltas; training still converges."""
    from repro.core import Triggerflow
    from repro.core.fedlearn import FederatedLearningOrchestrator, ObjectStore

    rng = np.random.default_rng(2)
    w_true = rng.normal(size=8)
    shards = []
    for _ in range(6):
        X = rng.normal(size=(120, 8))
        shards.append((X, (X @ w_true > 0).astype(float)))
    store = ObjectStore()
    wire = {"bytes": 0, "raw": 0}

    def client(args):
        base = np.asarray(store.get(args["model"]))
        X, y = shards[args["client"]]
        w = base.copy()
        for _ in range(4):
            p = 1 / (1 + np.exp(-(X @ w)))
            w -= 0.5 * X.T @ (p - y) / len(y)
        packed = compress_delta(w, base)
        wire["bytes"] += compressed_bytes(packed)
        wire["raw"] += w.astype(np.float32).nbytes
        return {"round": args["round"],
                "result": store.put(f"d/{args['round']}/{args['client']}", packed)}

    def aggregate(keys, st_):
        base_key = f"model/{rounds_seen[0]}"
        base = np.asarray(st_.get(base_key))
        ws = [apply_delta(base, st_.get(k)) for k in keys]
        rounds_seen[0] += 1
        return np.mean(ws, axis=0).tolist()

    rounds_seen = [0]
    tf = Triggerflow(inline_functions=True)
    fl = FederatedLearningOrchestrator(tf, "flc", client, aggregate,
                                       n_clients=6, rounds=3, threshold=1.0,
                                       object_store=store)
    fl.deploy()
    out = fl.start(init_model=np.zeros(8).tolist(), timeout=60)
    assert out["status"] == "succeeded"
    w = np.asarray(store.get(out["result"]["model"]))
    Xt = np.random.default_rng(3).normal(size=(500, 8))
    acc = (((Xt @ w) > 0) == ((Xt @ w_true) > 0)).mean()
    assert acc > 0.9
    # 8-dim toy deltas: 8B payload + 8B scale = exactly 2x; real models →4x
    assert wire["bytes"] < wire["raw"] / 1.9
