"""Failure-policy plane: retries with backoff, poison quarantine, breakers.

Covers:
* RetryPolicy / CircuitBreaker / call_with_timeout unit contracts
  (deterministic backoff schedules, open → half-open → closed lifecycle),
* scalar-plane retry-then-success, poison quarantine with structured DLQ
  metadata, per-attempt action timeouts, and backoff deferral (no hot
  redelivery of a backing-off event),
* batched-action poison-slice isolation: the healthy remainder of a failed
  batch commits, only the poison events quarantine — identical to the
  scalar oracle,
* DLQ reason taxonomy across store families (memory + file): ``redrive``
  reason filters, ``dlq_by_reason`` breakdowns, metadata riding redrive,
* the thread pool's crash-loop breaker gating ``start_shards``,
* retry-count durability across a real SIGKILL on the process runtime: the
  attempt counter continues from the durable checkpoint instead of
  restarting from zero.
"""
import time

import pytest

from repro.bus import PartitionedEventStore, ProcessShardPool, ShardedWorkerPool
from repro.chaos.soak import soak_child_init
from repro.core import (FileEventStore, MemoryEventStore, Triggerflow,
                        make_trigger, termination_event)
from repro.core.events import CloudEvent
from repro.core.functions import FunctionBackend
from repro.core.actions import register_action
from repro.core.policy import (ActionTimeout, CircuitBreaker, RETRY_STATE_KEY,
                               REASON_ACTION_ERROR, REASON_DISABLED,
                               REASON_TIMEOUT, RetryPolicy, call_with_timeout,
                               coerce_retry_policy, dlq_meta, dlq_reason,
                               quarantined, reason_counter_name)
from repro.core.statestore import MemoryStateStore


# -- unit: RetryPolicy -----------------------------------------------------------

def test_backoff_schedule_deterministic():
    pol = RetryPolicy(max_attempts=5, backoff_base=0.1, backoff_factor=2.0,
                      backoff_max=0.5, jitter=0.2)
    sched = [pol.backoff(n, "ev-1") for n in range(1, 5)]
    assert sched == [pol.backoff(n, "ev-1") for n in range(1, 5)]  # replayable
    # exponential, capped, and jitter only stretches (never shortens)
    assert 0.1 <= sched[0] < 0.1 * 1.2 + 1e-9
    assert 0.2 <= sched[1] < 0.2 * 1.2 + 1e-9
    assert sched[3] <= 0.5 * 1.2
    # jitter is keyed by event id: two events don't sync their retries
    assert pol.backoff(1, "ev-1") != pol.backoff(1, "ev-2")
    # no jitter → exact exponential
    flat = RetryPolicy(backoff_base=0.1, jitter=0.0)
    assert flat.backoff(2, "x") == 0.2


def test_coerce_retry_policy_roundtrip():
    assert coerce_retry_policy(None) is None
    d = coerce_retry_policy(RetryPolicy(max_attempts=2, action_timeout=1.5))
    assert d["max_attempts"] == 2 and d["action_timeout"] == 1.5
    assert RetryPolicy.from_dict(d).action_timeout == 1.5
    assert coerce_retry_policy({"max_attempts": 7})["max_attempts"] == 7
    with pytest.raises(TypeError):
        coerce_retry_policy("3 tries")
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


def test_quarantined_metadata_helpers():
    ev = termination_event("s", 1)
    tagged = quarantined(ev, REASON_TIMEOUT, attempts=3,
                         first_failure=10.0, last_failure=12.0)
    assert tagged.id == ev.id and tagged.subject == ev.subject
    assert ev.ext in (None, {}) or "tfdlq" not in ev.ext  # original untouched
    meta = dlq_meta(tagged)
    assert meta == {"reason": REASON_TIMEOUT, "attempts": 3,
                    "first_failure": 10.0, "last_failure": 12.0}
    assert dlq_reason(tagged) == REASON_TIMEOUT
    assert dlq_reason(ev) == REASON_DISABLED  # legacy entries default
    assert reason_counter_name(REASON_ACTION_ERROR) == "tf_poison_action_error_total"
    assert reason_counter_name(REASON_DISABLED) == "tf_quarantined_disabled_total"


def test_call_with_timeout():
    assert call_with_timeout(None, lambda: 42) == 42
    assert call_with_timeout(5.0, lambda: 42) == 42
    with pytest.raises(KeyError):
        call_with_timeout(5.0, lambda: {}["missing"])
    with pytest.raises(ActionTimeout):
        call_with_timeout(0.05, time.sleep, 5.0)


# -- unit: CircuitBreaker --------------------------------------------------------

def test_breaker_lifecycle():
    t = [0.0]
    br = CircuitBreaker(threshold=3, backoff_base=1.0, backoff_factor=2.0,
                        backoff_max=8.0, cooldown=5.0, clock=lambda: t[0])
    # first crash restarts free; second starts the backoff ladder
    br.record_crash()
    assert br.state == "closed" and br.allow_start(4) == 4
    br.record_crash()
    assert br.restart_backoff() == 1.0
    assert br.allow_start(4) == 0           # inside the backoff window
    t[0] += 1.0
    assert br.allow_start(4) == 4           # window elapsed
    br.record_crash()                        # streak 3 → open
    assert br.state == "open" and br.opened_total == 1
    assert br.allow_start(4) == 0
    t[0] += 5.0                              # cooldown → half-open probe
    assert br.allow_start(4) == 1
    assert br.state == "half_open"
    assert br.allow_start(4) == 1            # still only the probe
    br.record_crash()                        # probe died → re-open
    assert br.state == "open" and br.opened_total == 2
    t[0] += 5.0
    assert br.allow_start(1) == 1            # second probe
    br.record_clean()                        # probe retired cleanly → closed
    assert br.state == "closed" and br.streak == 0
    assert br.allow_start(3) == 3
    snap = br.snapshot()
    assert snap["state"] == "closed" and snap["opened_total"] == 2


# -- scalar plane: retry / quarantine / timeout ----------------------------------

def _flaky_action(ctx, event, params):
    if event.data.get("poison"):
        raise RuntimeError("poison event")
    fails = event.data.get("fails", 0)
    seen = dict(ctx.get("seen") or {})
    n = seen.get(event.id, 0) + 1
    seen[event.id] = n
    ctx["seen"] = seen
    if n <= fails:
        raise RuntimeError(f"flaky attempt {n}/{fails}")
    done = dict(ctx.get("done") or {})
    done[event.id] = n
    ctx["done"] = done


def _flaky_batch(ctx, events, params):
    # slice-isolating contract: decide about the WHOLE slice before any
    # side effect, so a raise leaves nothing partially applied
    if any(e.data.get("poison") or
           e.data.get("fails", 0) >= (ctx.get("seen") or {}).get(e.id, 0) + 1
           for e in events):
        raise RuntimeError("slice contains a failing event")
    for e in events:
        _flaky_action(ctx, e, params)


register_action("fp_flaky", _flaky_action, batched=_flaky_batch)


def _drain(w, rounds=60):
    for _ in range(rounds):
        w.run_once(64)


def _policy_tf(retry, store=None, **worker_flags):
    tf = Triggerflow(event_store=store or MemoryEventStore(),
                     inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("w")
    tf.add_trigger("w", make_trigger(
        "s", condition={"name": "true"}, action={"name": "fp_flaky"},
        trigger_id="t", transient=False, retry=retry))
    w = tf.worker("w")
    w.keep_event_log = False
    for k, v in worker_flags.items():
        setattr(w, k, v)
    return tf, w


def test_scalar_retry_then_success():
    tf, w = _policy_tf({"max_attempts": 4, "backoff_base": 0.0, "jitter": 0.0})
    ev = CloudEvent(subject="s", data={"fails": 2}, id="flaky-1")
    tf.event_store.publish("w", ev)
    _drain(w)
    ctx = w.context_of("t")
    assert ctx["done"] == {"flaky-1": 3}          # succeeded on attempt 3
    assert ctx.get(RETRY_STATE_KEY) in (None, {})  # cleared on success
    assert w.stats.action_retries == 2
    assert w.stats.poison_events == 0
    assert w.stats.fires == 1                      # retries are not fires
    assert tf.event_store.lag("w") == 0            # committed after success
    assert tf.event_store.dlq_size("w") == 0


def test_scalar_poison_quarantine_with_metadata():
    tf, w = _policy_tf({"max_attempts": 3, "backoff_base": 0.0, "jitter": 0.0})
    tf.event_store.publish_batch("w", [
        CloudEvent(subject="s", data={"poison": True}, id="bad-1"),
        CloudEvent(subject="s", data={}, id="good-1"),
    ])
    _drain(w)
    # healthy neighbour fired and committed; poison quarantined, not hot-looped
    assert w.context_of("t")["done"] == {"good-1": 1}
    assert tf.event_store.lag("w") == 0
    assert tf.event_store.dlq_by_reason("w") == {REASON_ACTION_ERROR: 1}
    assert w.stats.poison_events == 1
    assert w.stats.dlq_events == 1
    assert w.stats.action_retries == 2            # attempts 1 and 2 retried
    # structured metadata rides the DLQ entry through redrive
    assert tf.event_store.redrive("w", reasons=(REASON_DISABLED,)) == 0
    assert tf.event_store.redrive("w", reasons=(REASON_ACTION_ERROR,)) == 1
    redriven = [e for e in tf.event_store.consume("w", 10) if e.id == "bad-1"]
    meta = dlq_meta(redriven[0])
    assert meta["reason"] == REASON_ACTION_ERROR
    assert meta["attempts"] == 3
    assert meta["first_failure"] <= meta["last_failure"]


def test_action_timeout_quarantine():
    register_action("fp_sleepy", lambda ctx, e, p: time.sleep(e.data["dur"]))
    tf = Triggerflow(inline_functions=True, commit_policy="every_batch")
    tf.create_workflow("w")
    tf.add_trigger("w", make_trigger(
        "s", condition={"name": "true"}, action={"name": "fp_sleepy"},
        trigger_id="t", transient=False,
        retry={"max_attempts": 1, "action_timeout": 0.05}))
    w = tf.worker("w")
    tf.event_store.publish_batch("w", [
        CloudEvent(subject="s", data={"dur": 0.4}, id="slow-1"),
        CloudEvent(subject="s", data={"dur": 0.0}, id="fast-1"),
    ])
    _drain(w, rounds=10)
    assert w.stats.action_timeouts == 1
    assert w.stats.fires == 1                     # only the fast one
    assert tf.event_store.dlq_by_reason("w") == {REASON_TIMEOUT: 1}
    assert tf.event_store.lag("w") == 0


def test_backoff_defers_instead_of_hot_redelivery():
    tf, w = _policy_tf({"max_attempts": 3, "backoff_base": 0.15,
                        "backoff_factor": 1.0, "jitter": 0.0})
    tf.event_store.publish("w", CloudEvent(subject="s", data={"fails": 1},
                                           id="slow-retry"))
    w.run_once(64)                                # attempt 1 fails
    attempts_now = w.context_of("t")["seen"]["slow-retry"]
    assert attempts_now == 1
    for _ in range(20):                           # hot loop would re-run here
        w.run_once(64)
    assert w.context_of("t")["seen"]["slow-retry"] == 1  # deferred, not spun
    time.sleep(0.2)                               # backoff window elapses
    _drain(w, rounds=5)
    assert w.context_of("t")["done"] == {"slow-retry": 2}
    assert tf.event_store.lag("w") == 0


# -- batched-action poison-slice isolation vs the scalar oracle ------------------

def _isolation_run(action_plane):
    tf, w = _policy_tf({"max_attempts": 2, "backoff_base": 0.0, "jitter": 0.0},
                       action_plane=action_plane)
    events = []
    for i in range(12):
        poison = i % 4 == 0
        events.append(CloudEvent(
            subject="s", data={"poison": True} if poison else {},
            id=("bad-%d" if poison else "good-%d") % i))
    tf.event_store.publish_batch("w", events)
    _drain(w)
    ctx = w.context_of("t")
    return (dict(ctx.get("done") or {}), tf.event_store.dlq_by_reason("w"),
            tf.event_store.lag("w"), w.stats.poison_events)


def test_batched_action_poison_isolation_matches_scalar_oracle():
    batched = _isolation_run(True)
    scalar = _isolation_run(False)
    assert batched == scalar
    done, dlq, lag, poison = batched
    assert set(done) == {f"good-{i}" for i in range(12) if i % 4 != 0}
    assert dlq == {REASON_ACTION_ERROR: 3}
    assert lag == 0 and poison == 3


# -- DLQ reason taxonomy across store families -----------------------------------

@pytest.fixture(params=["memory", "file"])
def plain_store(request, tmp_path):
    if request.param == "memory":
        return MemoryEventStore()
    return FileEventStore(str(tmp_path / "events"))


def test_dlq_reasons_across_store_families(plain_store):
    tf, w = _policy_tf({"max_attempts": 2, "backoff_base": 0.0, "jitter": 0.0},
                       store=plain_store)
    tf.add_trigger("w", make_trigger(
        "d", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="td", transient=False))
    w.set_trigger_enabled("td", False)            # → ``disabled`` DLQ class
    tf.event_store.publish_batch("w", [
        CloudEvent(subject="s", data={"poison": True}, id="bad-1"),
        termination_event("d", 1),
    ])
    _drain(w, rounds=10)
    assert plain_store.dlq_by_reason("w") == {
        REASON_ACTION_ERROR: 1, REASON_DISABLED: 1}
    assert plain_store.dlq_size("w") == 2
    # the reasons filter redrives selectively — poison stays put
    assert plain_store.redrive("w", reasons=(REASON_DISABLED,)) == 1
    assert plain_store.dlq_by_reason("w") == {REASON_ACTION_ERROR: 1}
    # unfiltered redrive is the legacy everything behaviour
    assert plain_store.redrive("w") == 1
    assert plain_store.dlq_size("w") == 0


def test_trigger_retry_policy_survives_spec_roundtrip():
    trg = make_trigger("s", condition={"name": "true"},
                       action={"name": "noop"}, trigger_id="t",
                       retry={"max_attempts": 6, "backoff_base": 0.01})
    from repro.core.triggers import Trigger
    spec = trg.to_dict()
    assert spec["retry_policy"]["max_attempts"] == 6
    back = Trigger.from_dict(spec)
    assert back.retry_policy["max_attempts"] == 6
    # triggers without a policy don't grow a key (wire-format compat)
    bare = make_trigger("s", trigger_id="t2")
    assert "retry_policy" not in bare.to_dict()


# -- thread pool: crash-loop breaker gates restarts ------------------------------

def test_pool_breaker_gates_start_shards():
    store = PartitionedEventStore(4)
    pool = ShardedWorkerPool(
        store, MemoryStateStore(), FunctionBackend(store, inline=True),
        commit_policy="every_batch",
        breaker={"threshold": 2, "backoff_base": 0.0, "cooldown": 0.15})
    pool.add_trigger("w", make_trigger(
        "s", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="t", transient=False))
    pool.set_shard_count("w", 1)
    pool.crash_shard("w", pool.shard_ids("w")[0])   # streak 1: restart free
    assert pool.start_shards("w", 1)
    assert pool.shard_count("w") == 1
    pool.crash_shard("w", pool.shard_ids("w")[0])   # streak 2 → circuit opens
    br = pool.breaker_of("w")
    assert br.state == "open"
    pool.start_shards("w", 2)
    assert pool.shard_count("w") == 0               # starts denied while open
    snap = pool.obs_snapshot("w")
    assert snap["counters"]["tf_circuit_open_total"] == 1
    assert "breaker=" in pool.failure_diagnostics("w")
    time.sleep(0.2)                                  # cooldown elapses
    pool.start_shards("w", 2)
    assert pool.shard_count("w") == 1               # single half-open probe
    assert br.state == "half_open"
    pool.remove_shard("w", pool.shard_ids("w")[0])  # clean retire → closed
    assert br.state == "closed"
    pool.start_shards("w", 2)
    assert pool.shard_count("w") == 2
    pool.stop_all()


# -- breaker x lease fencing: no sanctioned re-acquisition while open ------------

def _leased_pool(tmp_path, breaker):
    from repro.bus import FilePartitionedEventStore
    store = FilePartitionedEventStore(
        str(tmp_path / "bus"), 2, fsync=False, lease_owner="node-a")
    pool = ShardedWorkerPool(
        store, MemoryStateStore(), FunctionBackend(store, inline=True),
        commit_policy="every_batch", breaker=breaker)
    pool.add_trigger("w", make_trigger(
        "s", condition={"name": "true"}, action={"name": "noop"},
        trigger_id="t", transient=False))
    return store, pool


def _lease_epochs(store, wf="w"):
    return {p: int(h.rpartition("@e")[2])
            for p, h in store.lease_holders(wf).items()}


def test_open_breaker_blocks_lease_reacquisition(tmp_path):
    """Lease re-acquisition rides the assignment path, and the breaker gates
    assignment: while the circuit is open no shard starts, no rebalance
    runs, and the on-disk lease epochs must NOT advance — an epoch bump
    from a crash-looping pool would fence a healthy takeover node."""
    store, pool = _leased_pool(tmp_path, {"threshold": 2, "backoff_base": 0.0,
                                          "cooldown": 0.15})
    pool.set_shard_count("w", 1)                    # assignment → epoch 1
    assert set(_lease_epochs(store).values()) == {1}
    pool.crash_shard("w", pool.shard_ids("w")[0])   # streak 1: restart free
    pool.start_shards("w", 1)                       # re-assignment → epoch 2
    assert set(_lease_epochs(store).values()) == {2}
    pool.crash_shard("w", pool.shard_ids("w")[0])   # streak 2 → circuit opens
    assert pool.breaker_of("w").state == "open"
    for _ in range(3):                              # denied: no assignment,
        pool.start_shards("w", 1)                   # so no epoch movement
    assert pool.shard_count("w") == 0
    assert set(_lease_epochs(store).values()) == {2}
    time.sleep(0.2)                                 # cooldown → probe allowed
    pool.start_shards("w", 1)
    assert pool.breaker_of("w").state == "half_open"
    assert set(_lease_epochs(store).values()) == {3}
    pool.stop_all()


def test_fenced_half_open_probe_reopens_breaker(tmp_path):
    """A half-open probe whose lease was superseded mid-run dies on
    ``FencedWrite`` like any other owner write — and that death counts as a
    failed probe: the breaker re-opens instead of letting a fenced zombie
    keep probing against the new owner's epoch."""
    from repro.bus import FencedWrite, FilePartitionedEventStore
    store, pool = _leased_pool(tmp_path, {"threshold": 2, "backoff_base": 0.0,
                                          "cooldown": 0.05})
    pool.set_shard_count("w", 1)
    pool.crash_shard("w", pool.shard_ids("w")[0])
    pool.start_shards("w", 1)
    pool.crash_shard("w", pool.shard_ids("w")[0])   # → open
    br = pool.breaker_of("w")
    assert br.state == "open" and br.opened_total == 1
    time.sleep(0.1)
    pool.start_shards("w", 1)                       # half-open probe
    assert br.state == "half_open"
    # another node takes the leases AFTER the probe's assignment: the
    # probe's next owner-side write runs under a superseded epoch
    other = FilePartitionedEventStore(
        str(tmp_path / "bus"), 2, fsync=False, lease_owner="node-b")
    other.reacquire_partition_leases("w", [0, 1])
    store.publish_batch("w", [termination_event(f"s{i}", i)
                              for i in range(8)])
    member = pool.shard_ids("w")[0]
    with pytest.raises(FencedWrite):
        pool.run_shard_once("w", member)            # commit fenced, loudly
    assert store.fenced_writes >= 1
    pool.crash_shard("w", member)                   # the fenced probe died
    assert br.state == "open" and br.opened_total == 2
    assert pool.obs_snapshot("w")["counters"]["tf_fenced_writes_total"] >= 1
    assert "leases=" in pool.failure_diagnostics("w")
    pool.stop_all()


# -- process runtime: attempt counts survive SIGKILL -----------------------------

def test_proc_retry_counts_durable_across_sigkill(tmp_path):
    """Kill the shard mid-retry; the replacement continues the attempt count
    from the durable checkpoint.  If the counter reset on crash, the
    replacement would burn the full budget again (3 retries); instead it
    only spends what the checkpoint says is left."""
    pool = ProcessShardPool(str(tmp_path / "pool"), num_partitions=2,
                            batch_size=64, child_init=soak_child_init)
    try:
        pool.create_workflow("w")
        pool.add_trigger("w", make_trigger(
            "s0", condition={"name": "true"},
            action={"name": "chaos_record", "seed": 0, "fail_pct": 0},
            trigger_id="t", transient=False,
            retry={"max_attempts": 4, "backoff_base": 0.25,
                   "backoff_factor": 1.0, "jitter": 0.0}))
        # chaos_record treats poison-* ids as always-failing
        pool.publish("w", CloudEvent(subject="s0", data={}, id="poison-1"))
        pool.start_shards("w", 1)
        deadline = time.monotonic() + 20
        while True:  # wait for a checkpointed (durable) attempt record
            rec = pool.trigger_context("w", "t").get(RETRY_STATE_KEY, {})
            if rec.get("poison-1", [0])[0] >= 1:
                break
            assert time.monotonic() < deadline, "no attempt ever checkpointed"
            time.sleep(0.01)
        pool.crash_shard("w", pool.shard_ids("w")[0])       # real SIGKILL
        k = pool.trigger_context("w", "t")[RETRY_STATE_KEY]["poison-1"][0]
        assert k >= 1
        pool.start_shards("w", 1)
        while pool.event_store.dlq_size("w") < 1:
            assert time.monotonic() < deadline, (
                "poison event never quarantined: "
                + pool.failure_diagnostics("w"))
            time.sleep(0.02)
        snap = pool.obs_snapshot("w")
        pool.stop_all()
        assert pool.event_store.dlq_by_reason("w") == {REASON_ACTION_ERROR: 1}
        assert pool.event_store.lag("w") == 0
        # the replacement's counters cover only the REMAINING budget: the
        # killed shard's k attempts were not repeated (durable counter)
        assert snap["counters"]["tf_poison_events_total"] == 1
        assert snap["counters"].get("tf_action_retries_total", 0) == 3 - k
        # final quarantine metadata carries the full cross-crash attempt count
        p = pool.event_store.partition_for("s0", "w")
        assert pool.event_store.redrive("w", reasons=(REASON_ACTION_ERROR,)) == 1
        ev = [e for e in pool.event_store.consume_partitions("w", [p], 10)
              if e.id == "poison-1"][0]
        assert dlq_meta(ev)["attempts"] == 4
    finally:
        pool.stop_all()
